"""Sharded metadata plane (ISSUE-4 tentpole).

Covers:
  * ``ShardedIndex`` — partition/merge equivalence against an unsharded
    ``GlobalIndex`` on clean and holed chains, per-shard LRU eviction
    distribution, ownership fan-out;
  * ``ShardedRpcIndexClient`` — the same ops over S live rings, including
    chunking through tiny slots and TRUE parallel posting (a barrier
    handler that only releases once every shard's request has arrived
    deadlocks a sequential client, passes a post-all-first one);
  * cluster integration — ``index_shards=1`` reproduces the unsharded
    ``index_rpc`` summary stats bit-identically, ``index_shards=4``
    matches the in-process stats on hole-free traffic with all rings
    served.
"""

import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import wire
from repro.core.index import (
    GlobalIndex,
    ShardedIndex,
    partition_keys,
    shard_of_key,
)
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.rpc import CxlRpcClient, CxlRpcServer, ShmRing
from repro.serving.request import Request
from repro.serving.scheduler import Cluster, ClusterConfig

LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)


def _pool(n_blocks=2048):
    return BelugaPool(LAYOUT, n_blocks=n_blocks, n_shards=8, backing="meta")


def _publish_chain(pool, idx, doc, chain_len):
    tokens = [doc * 10_000 + i for i in range(chain_len * 16)]
    keys = idx.keys_for(tokens)
    blocks = pool.allocate(len(keys))
    idx.publish_many(keys, blocks, pool.write_blocks(blocks), 16)
    return tokens, keys, blocks


def _sharded_rpc(sidx, payload_bytes=1 << 14, n_slots=8):
    rings = [
        ShmRing(n_slots=n_slots, payload_bytes=payload_bytes)
        for _ in sidx.shards
    ]
    servers = [
        CxlRpcServer(r, wire.make_index_handler(sh, max_reply=r.payload_bytes)).start()
        for r, sh in zip(rings, sidx.shards)
    ]
    clients = [CxlRpcClient(r) for r in rings]
    proxy = wire.ShardedRpcIndexClient(
        clients, LAYOUT.block_tokens, hasher=sidx.hasher
    )
    return proxy, servers, clients


# ---------------------------------------------------------------------------
# ShardedIndex (in-process)
# ---------------------------------------------------------------------------


def test_shard_routing_is_total_and_order_preserving():
    keys = [bytes([i]) * 16 for i in range(64)]
    key_lists, pos_lists = partition_keys(keys, 4)
    assert sum(map(len, key_lists)) == 64
    for s, (kl, pl) in enumerate(zip(key_lists, pos_lists)):
        assert pl == sorted(pl)  # chain order survives the split
        assert all(shard_of_key(k, 4) == s for k in kl)
        assert [keys[i] for i in pl] == kl


@settings(max_examples=20, deadline=None)
@given(
    n_shards=st.integers(1, 5),
    chain_len=st.integers(1, 48),
    cut=st.integers(0, 48),
    seed=st.integers(0, 2**31),
)
def test_sharded_index_matches_unsharded_reference(n_shards, chain_len, cut, seed):
    """match/lookup/filter over a sharded front == unsharded GlobalIndex,
    including a chain whose published prefix ends mid-way (``cut``)."""
    cut = min(cut, chain_len)
    pool_a, pool_b = _pool(), _pool()
    ref = GlobalIndex(pool_a)
    sidx = ShardedIndex(pool_b, n_shards)
    tokens = [seed % 1000 * 100 + i for i in range(chain_len * 16)]
    keys = ref.keys_for(tokens)
    blocks_a = pool_a.allocate(cut) if cut else []
    ref.publish_many(list(keys[:cut]), blocks_a, pool_a.write_blocks(blocks_a), 16)
    blocks_b = pool_b.allocate(cut) if cut else []
    sidx.publish_many(list(keys[:cut]), blocks_b, pool_b.write_blocks(blocks_b), 16)

    got = sidx.match_prefix(tokens)
    want = ref.match_prefix(tokens)
    assert [k for k, _, _ in got] == [k for k, _, _ in want]
    assert len(got) == cut
    assert [b for _, b, _ in got] == blocks_b
    assert sidx.filter_unpublished(keys) == ref.filter_unpublished(keys)
    assert [
        None if e is None else e.block_id for e in sidx.lookup_many(keys[:cut])
    ] == blocks_b
    k_o, b_o, _ = sidx.owners_of(blocks_b)
    assert (k_o, b_o) == (list(keys[:cut]), blocks_b)
    assert sidx.keys_of_blocks(blocks_b) == list(keys[:cut])
    assert sidx.stats()["entries"] == cut


def test_sharded_match_stops_at_first_hole_not_shard_local_prefix():
    """A stale entry mid-chain must cut the GLOBAL prefix even when the
    owning shard's own sub-chain continues past it."""
    pool = _pool()
    sidx = ShardedIndex(pool, 3)
    tokens, keys, blocks = _publish_chain(pool, sidx, 1, 24)
    hole = 7
    pool.release([blocks[hole]])  # epoch bump: entry goes stale
    hits = sidx.match_prefix(tokens)
    assert len(hits) == hole
    assert [b for _, b, _ in hits] == blocks[:hole]


def test_sharded_evict_lru_spreads_over_shards_and_drains():
    pool = _pool()
    sidx = ShardedIndex(pool, 4)
    chains = [_publish_chain(pool, sidx, d, 8) for d in range(4)]
    total = 32
    freed = sidx.evict_lru(10)
    assert len(freed) == 10
    assert sidx.stats()["entries"] == total - 10
    # drain pass picks up the rest even when quotas land on dry shards
    freed2 = sidx.evict_lru(1000)
    assert len(freed2) == total - 10
    assert sidx.stats()["entries"] == 0
    assert pool.free_blocks() == pool.n_blocks
    del chains


def test_sharded_evict_pressure_spares_hot_shard_with_idle_cold_capacity():
    """Per-shard eviction pressure (ROADMAP open item): quotas weight by
    shard OCCUPANCY, so a hot shard holding a handful of live entries is
    spared while a cold shard with plenty of idle entries absorbs the
    whole eviction.  The old blind ceil(n/S) split would have taken half
    the quota out of the hot shard."""
    pool = _pool()
    sidx = ShardedIndex(pool, 2)
    # craft digests routed to a specific shard
    hot_keys, cold_keys = [], []
    i = 0
    while len(hot_keys) < 4 or len(cold_keys) < 24:
        k = bytes([i % 256, i // 256]) + b"\x00" * 14
        (hot_keys if shard_of_key(k, 2) == 1 else cold_keys).append(k)
        i += 1
    hot_keys, cold_keys = hot_keys[:4], cold_keys[:24]
    cold_blocks = pool.allocate(24)
    sidx.publish_many(cold_keys, cold_blocks, pool.write_blocks(cold_blocks), 16)
    hot_blocks = pool.allocate(4)
    sidx.publish_many(hot_keys, hot_blocks, pool.write_blocks(hot_blocks), 16)
    sidx.match_prefix_keys(hot_keys)  # hot shard is busy serving these
    freed = sidx.evict_lru(12)
    assert len(freed) == 12
    assert set(freed) <= set(cold_blocks)  # pressure lands on the cold shard
    # every hot entry survived (old policy evicted ceil(12/2)=6 incl. all 4)
    assert all(e is not None for e in sidx.lookup_many(hot_keys))
    # once the cold shard runs dry the hot shard is still evictable
    freed2 = sidx.evict_lru(1000)
    assert len(freed2) == 24 + 4 - 12
    assert sidx.stats()["entries"] == 0


def test_sharded_rpc_evict_pressure_matches_in_process_policy():
    """The RPC front must run the SAME occupancy-weighted policy: freed
    lists agree shard-state for shard-state with the in-process front."""
    pool_a, pool_b = _pool(), _pool()
    ref = ShardedIndex(pool_a, 3)
    sidx = ShardedIndex(pool_b, 3)
    for doc in range(3):
        for p, idx in ((pool_a, ref), (pool_b, sidx)):
            tokens = [doc * 10_000 + i for i in range(10 * 16)]
            keys = idx.keys_for(tokens)
            blocks = p.allocate(len(keys))
            idx.publish_many(keys, blocks, p.write_blocks(blocks), 16)
    proxy, servers, _ = _sharded_rpc(sidx)
    try:
        for n in (5, 9, 100):
            assert proxy.evict_lru(n) == ref.evict_lru(n)
    finally:
        for s in servers:
            s.stop()


def test_sharded_remap_routes_by_key_and_checks_old_identity():
    pool = _pool()
    sidx = ShardedIndex(pool, 4)
    _, keys, blocks = _publish_chain(pool, sidx, 2, 12)
    _, _, eps = sidx.owners_of(blocks)
    nb = pool.allocate(12)
    ne = pool.write_blocks(nb)
    stale = list(eps)
    stale[5] += 99  # one remap must lose the compare-and-swap
    ok = sidx.remap_many(list(keys), blocks, stale, nb, ne)
    assert ok == [True] * 5 + [False] + [True] * 6
    for i, k in enumerate(keys):
        want = nb[i] if ok[i] else blocks[i]
        assert sidx.lookup(k).block_id == want


def test_sharded_on_evict_fires_from_every_shard():
    pool = _pool()
    sidx = ShardedIndex(pool, 4)
    seen = []
    sidx.on_evict = seen.append
    _, keys, _ = _publish_chain(pool, sidx, 3, 16)
    sidx.evict_lru(16)
    assert sorted(k for batch in seen for k in batch) == sorted(keys)
    assert len(seen) >= 2  # more than one shard contributed


# ---------------------------------------------------------------------------
# ShardedRpcIndexClient (live rings)
# ---------------------------------------------------------------------------


def test_sharded_rpc_client_matches_in_process_sharded_index():
    pool = _pool()
    sidx = ShardedIndex(pool, 4)
    tokens, keys, blocks = _publish_chain(pool, sidx, 1, 30)
    proxy, servers, _ = _sharded_rpc(sidx)
    try:
        assert proxy.match_prefix(tokens) == sidx.match_prefix(tokens)
        assert proxy.filter_unpublished(keys) == []
        assert [e.block_id for e in proxy.lookup_many(keys)] == blocks
        assert proxy.owners_of(blocks) == sidx.owners_of(blocks)
        # all rings actually served traffic
        assert all(s.served > 0 for s in servers)
        # migration over the wire: remap + evict_blocks
        nb = pool.allocate(3)
        ne = pool.write_blocks(nb)
        _, _, eps = proxy.owners_of(blocks[:3])
        assert proxy.remap_many(list(keys[:3]), blocks[:3], eps, nb, ne) == [True] * 3
        pool.release(blocks[:3])
        assert [b for _, b, _ in proxy.match_prefix(tokens)][:3] == nb
        assert sorted(proxy.evict_blocks(nb)) == sorted(nb)
        assert len(proxy.match_prefix(tokens)) == 0
    finally:
        for s in servers:
            s.stop()


def test_sharded_rpc_client_chunks_through_tiny_slots():
    pool = _pool()
    sidx = ShardedIndex(pool, 3)
    tokens, keys, blocks = _publish_chain(pool, sidx, 2, 60)
    proxy, servers, _ = _sharded_rpc(sidx, payload_bytes=128)
    try:
        assert proxy._max_match == 7  # ~20-key sub-chains must split
        assert [b for _, b, _ in proxy.match_prefix(tokens)] == blocks
        pool.release([blocks[2]])  # early hole: later chunks can't extend
        assert len(proxy.match_prefix(tokens)) == 2
        assert proxy.filter_unpublished(keys) == [2]
        freed = proxy.evict_lru(1000)
        assert len(freed) == 59
    finally:
        for s in servers:
            s.stop()


def test_sharded_rpc_posts_all_shards_before_collecting():
    """TRUE parallel outstanding RPCs: every shard's handler blocks until
    ALL shards have received this op's sub-request. A client that
    collected shard 0 before posting to shard 1 would deadlock here."""
    pool = _pool()
    S = 3
    sidx = ShardedIndex(pool, S)
    tokens, keys, blocks = _publish_chain(pool, sidx, 1, 24)
    barrier = threading.Barrier(S)
    rings = [ShmRing(n_slots=4, payload_bytes=1 << 14) for _ in range(S)]

    def make_handler(shard):
        inner = wire.make_index_handler(shard)

        def handler(payload: bytes) -> bytes:
            barrier.wait(timeout=10)  # releases only when all S arrive
            return inner(payload)

        return handler

    servers = [
        CxlRpcServer(r, make_handler(sh)).start()
        for r, sh in zip(rings, sidx.shards)
    ]
    try:
        proxy = wire.ShardedRpcIndexClient(
            [CxlRpcClient(r) for r in rings],
            LAYOUT.block_tokens,
            hasher=sidx.hasher,
        )
        # every key list is non-empty for 24 keys over 3 shards, so the
        # barrier needs all three sub-requests in flight at once
        assert all(kl for kl in partition_keys(keys, S)[0])
        hits = proxy.match_prefix_keys(keys)
        assert [b for _, b, _ in hits] == blocks
    finally:
        for s in servers:
            s.stop()


def test_sharded_rpc_fanout_collects_posted_slots_on_error():
    """If one shard errors, replies already posted to other shards are
    still collected (or quarantined) — no slot leaks, and the next op
    runs clean."""
    pool = _pool()
    sidx = ShardedIndex(pool, 2)
    tokens, keys, blocks = _publish_chain(pool, sidx, 4, 16)
    proxy, servers, clients = _sharded_rpc(sidx, n_slots=2)
    try:
        # kill one shard's server so its collect times out
        servers[1].stop()
        with pytest.raises(TimeoutError):
            proxy._fanout(
                {0: wire.encode_match(keys[:1]), 1: wire.encode_match(keys[1:2])},
                timeout=0.2,
            )
        assert clients[0].stats.requests >= 1  # shard 0 was collected
        assert clients[1].stats.timeouts == 1
        # shard 0 still fully usable
        assert proxy.shards[0].match_prefix_keys(
            partition_keys(keys, 2)[0][0][:1]
        )
    finally:
        for s in servers:
            s.stop()


# ---------------------------------------------------------------------------
# cluster integration: index_shards in the serving sim
# ---------------------------------------------------------------------------


def _run_small_cluster(**kw):
    c = Cluster(
        ClusterConfig(
            n_engines=2, pool_blocks=2048, hbm_slots_per_engine=256,
            index_rpc_slots=8, **kw,
        ),
        LAYOUT,
    )
    try:
        base = list(range(512))
        for i in range(8):
            c.dispatch(Request(f"r{i}", base, 8, 0.0))
        s1 = c.run()
        t0 = max(e.clock for e in c.engines)
        tail = [Request(f"h{i}", base, 8, t0) for i in range(4)]
        for r in tail:
            c.dispatch(r)
        s2 = c.run()
        served = [srv.served for srv in c._rpc_servers]
        assert all(r.hit_tokens > 0 for r in tail)
        return _strip_shards(s1), _strip_shards(s2), served
    finally:
        c.close()


def _strip_shards(stats):
    stats = dict(stats)
    stats["index"] = {k: v for k, v in stats["index"].items() if k != "shards"}
    return stats


def test_cluster_index_shards_summary_stats_bit_identical():
    """index_shards=1 over RPC == today's unsharded index_rpc ==
    in-process, stat for stat; index_shards=4 matches too on this
    hole-free workload (and every ring served real traffic)."""
    in_proc = _run_small_cluster()
    rpc_s1 = _run_small_cluster(index_rpc=True)
    rpc_s4 = _run_small_cluster(index_rpc=True, index_shards=4)
    assert in_proc[:2] == rpc_s1[:2]
    assert in_proc[:2] == rpc_s4[:2]
    assert rpc_s1[2] and all(n > 0 for n in rpc_s1[2])
    assert len(rpc_s4[2]) == 4 and all(n > 0 for n in rpc_s4[2])


def test_cluster_index_shards_in_process_mode():
    """Sharding without RPC: the engines call the ShardedIndex front
    directly; same summary stats on hole-free traffic."""
    in_proc = _run_small_cluster()
    sharded = _run_small_cluster(index_shards=4)
    assert in_proc[:2] == sharded[:2]

"""End-to-end behaviour tests for the paper's system (Beluga-KVCache).

These check the *claims*, not just the plumbing:
  C1  pooled KV reuse skips prefill and preserves outputs exactly;
  C2  single fused transfer vs per-fragment RDMA requests (§6.1);
  C3  epoch coherence: no reader ever consumes a recycled block (§5.1);
  C4  cache-oblivious scheduling balances load on the shared pool (§6.3);
  C5  interleaving spreads pool load across shards (O9);
  C6  the cluster survives instance loss + elastic scale-out with no KV
      rebalancing.
"""

import numpy as np

from repro.configs.registry import get_config
from repro.core.pool import PoolLayout
from repro.serving.request import Request
from repro.serving.scheduler import Cluster, ClusterConfig


LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)


def _reqs(n, in_len=1024, out_len=8, tag="r", arrival=0.0, distinct=False):
    base = list(range(in_len))
    out = []
    for i in range(n):
        toks = [50_000 + i] * in_len if distinct else list(base)
        out.append(Request(f"{tag}{i}", toks, out_len, arrival))
    return out


def test_c1_pool_reuse_exactness():
    from repro.serving.real_runner import RealEngine

    eng = RealEngine.create("qwen1.5-0.5b", max_len=96, pool_blocks=64)
    rng = np.random.default_rng(7)
    p1 = rng.integers(0, eng.cfg.vocab_size, size=48).tolist()
    out_cold, info_cold = eng.generate(p1, max_new=6)
    out_warm, info_warm = eng.generate(p1, max_new=6)
    assert info_cold["hit_tokens"] == 0 and info_warm["hit_tokens"] == 48
    assert out_cold == out_warm


def test_c2_fused_vs_fragmented_requests():
    from repro.core.pool import BelugaPool
    from repro.core.transfer import TransferEngine

    lay = PoolLayout.for_model(get_config("qwen3-32b"))
    be = TransferEngine(BelugaPool(lay, 64, 8, backing="meta"), mode="beluga")
    rd = TransferEngine(BelugaPool(lay, 64, 8, backing="meta"), mode="rdma")
    be.gather_write(be.pool.allocate(8), None)
    rd.gather_write(rd.pool.allocate(8), None)
    assert be.stats.requests_issued == 1  # one fused kernel
    # 8 blocks x 128 fragments / 30 sgl entries
    assert rd.stats.requests_issued >= 8 * 128 // 30


def test_c3_no_stale_reads_under_churn():
    from repro.core.index import GlobalIndex
    from repro.core.pool import BelugaPool
    from repro.core.transfer import TransferEngine

    pool = BelugaPool(LAYOUT, n_blocks=16, n_shards=8, backing="numpy")
    idx = GlobalIndex(pool)
    eng = TransferEngine(pool)
    rng = np.random.default_rng(0)
    for _round in range(30):
        tokens = rng.integers(0, 50, size=32).tolist()
        hits = idx.match_prefix(tokens)
        if hits:  # every advertised hit must still be epoch-valid
            eng.scatter_read([b for _, b, _ in hits], [e for _, _, e in hits])
        keys = idx.keys_for(tokens)
        missing = keys[len(hits):]
        if missing:
            try:
                blocks = pool.allocate(len(missing))
            except Exception:
                idx.evict_lru(4)
                continue
            kv = np.zeros((len(missing), LAYOUT.n_fragments, 16, 2, 8), np.float16)
            epochs = eng.gather_write(blocks, kv)
            for k, b, e in zip(missing, blocks, epochs):
                idx.publish(k, b, e, 16)


def test_c4_cache_oblivious_balances_load():
    res = {}
    for policy in ("cache_oblivious", "cache_aware"):
        c = Cluster(
            ClusterConfig(n_engines=4, policy=policy, pool_blocks=8192,
                          hbm_slots_per_engine=512),
            LAYOUT,
        )
        # same hot prefix for everyone: cache-aware herds onto one engine
        for r in _reqs(24, in_len=512):
            c.dispatch(r)
        c.run()
        t0 = max(e.clock for e in c.engines)
        for r in _reqs(24, in_len=512, tag="h", arrival=t0):
            c.dispatch(r)
        c.run()
        loads = [e.stats.busy_s for e in c.engines]
        res[policy] = max(loads) / max(min(loads), 1e-9)
    assert res["cache_oblivious"] <= res["cache_aware"] + 1e-6


def test_c5_interleaving_spreads_occupancy():
    c = Cluster(ClusterConfig(n_engines=2, pool_blocks=4096, interleave=True,
                              hbm_slots_per_engine=1024), LAYOUT)
    for r in _reqs(8, in_len=2048, distinct=True):
        c.dispatch(r)
    c.run()
    occ = c.pool.shard_occupancy()
    assert max(occ) - min(occ) <= max(2, 0.1 * max(occ)), occ


def test_c6_failure_and_elastic_scaleout():
    c = Cluster(ClusterConfig(n_engines=4, pool_blocks=8192,
                              hbm_slots_per_engine=512), LAYOUT)
    for r in _reqs(16, in_len=512, out_len=32):
        c.dispatch(r)
    for e in c.engines:
        e.advance(0.3)
    c.remove_engine(1)  # instance dies mid-flight
    c.add_engine()  # replacement joins; shared pool -> no KV migration
    stats = c.run()
    assert stats["n_done"] == 16
    # warm restart: the replacement engine can serve pool hits immediately
    t0 = max(e.clock for e in c.engines)
    tail = _reqs(4, in_len=512, tag="h", arrival=t0)
    for r in tail:
        c.engines[-1].submit(r, t0)
        c.requests.append(r)
    c.run()
    assert all(r.state == "done" for r in tail)
    assert any(r.hit_tokens > 0 for r in tail)

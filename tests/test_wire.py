"""Metadata wire protocol + RPC-ring hardening (PR 3).

Covers the ISSUE-3 satellite surface:
  * wire codec round-trip + truncation/garbage fuzz (never a crash,
    always ``WireError`` for malformed frames);
  * ``RpcIndexClient`` equivalence against the in-process ``GlobalIndex``,
    including chunked ops through a tiny ring slot;
  * timeout slot quarantine: a timed-out slot is NOT recycled while the
    server still owes it a response, so a late response can never leak
    into an unrelated caller;
  * concurrent clients under slot exhaustion;
  * ``keys_for`` aliasing: the shared cached chain is immutable and
    mutating the caller's token list cannot poison the memo;
  * the flat-array index internals (LRU order, growth, batch splice).
"""

import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import wire
from repro.core.index import GlobalIndex, PrefixHasher
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.rpc import (
    IDLE,
    REQ_READY,
    RESP_ERROR,
    RESP_READY,
    CxlRpcClient,
    CxlRpcServer,
    RpcError,
    ShmRing,
)

LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)


def _pool(n_blocks=1024, **kw):
    return BelugaPool(LAYOUT, n_blocks=n_blocks, n_shards=8, backing="meta", **kw)


def _published(n_chains=3, chain_len=8):
    pool = _pool()
    idx = GlobalIndex(pool)
    chains = []
    for d in range(n_chains):
        tokens = [d * 10_000 + i for i in range(chain_len * 16)]
        keys = idx.keys_for(tokens)
        blocks = pool.allocate(len(keys))
        idx.publish_many(keys, blocks, pool.write_blocks(blocks), 16)
        chains.append((tokens, keys, blocks))
    return pool, idx, chains


# ---------------------------------------------------------------------------
# codec round-trips + fuzz
# ---------------------------------------------------------------------------


def test_wire_roundtrip_all_ops():
    pool, idx, chains = _published()
    tokens, keys, blocks = chains[0]
    # match
    ids, eps = wire.decode_match_resp(
        wire.handle_request(idx, wire.encode_match(keys))
    )
    assert ids.tolist() == blocks
    # lookup with a hole
    probe = list(keys[:3]) + [b"\x99" * 16]
    li, le, lt = wire.decode_lookup_resp(
        wire.handle_request(idx, wire.encode_lookup(probe))
    )
    assert li.tolist()[:3] == blocks[:3] and li[3] == -1
    assert lt.tolist()[:3] == [16, 16, 16]
    # filter: everything valid -> empty; poke a hole -> position comes back
    assert wire.decode_filter_resp(
        wire.handle_request(idx, wire.encode_filter(keys))
    ) == []
    pool.release([blocks[2]])
    assert wire.decode_filter_resp(
        wire.handle_request(idx, wire.encode_filter(keys))
    ) == [2]
    # publish the hole back
    [nb] = pool.allocate(1)
    [ne] = pool.write_blocks([nb])
    n = wire.decode_publish_resp(
        wire.handle_request(idx, wire.encode_publish([keys[2]], [nb], [ne], 16))
    )
    assert n == 1
    assert idx.lookup(keys[2]).block_id == nb
    # evict
    freed = wire.decode_evict_resp(
        wire.handle_request(idx, wire.encode_evict(2))
    )
    assert len(freed) == 2
    # batch: two ops in one envelope
    resps = wire.decode_batch_resp(
        wire.handle_request(
            idx, wire.encode_batch([wire.encode_match(keys), wire.encode_evict(1)])
        )
    )
    assert len(resps) == 2


def test_wire_rejects_malformed():
    _, idx, _ = _published(1, 2)
    with pytest.raises(wire.WireError):
        wire.handle_request(idx, b"")
    with pytest.raises(wire.WireError):
        wire.handle_request(idx, bytes([99, 0, 0, 0, 0]))  # unknown op
    good = wire.encode_match([b"k" * 16, b"j" * 16])
    for cut in (1, 4, len(good) - 1):
        with pytest.raises(wire.WireError):
            wire.handle_request(idx, good[:cut])
    with pytest.raises(wire.WireError):
        wire.encode_match([b"short"])  # not a 16-byte digest


def test_publish_many_duplicate_key_resolves_to_last_occurrence():
    """A batch carrying the same key twice (only craftable via a wire
    OP_PUBLISH) must not leave a stale block->row reverse pointer at the
    first occurrence's block (regression vs the per-key seed loop)."""
    pool = _pool()
    idx = GlobalIndex(pool)
    [b1, b2] = pool.allocate(2)
    [e1, e2] = pool.write_blocks([b1, b2])
    k = b"\x42" * 16
    wire.handle_request(idx, wire.encode_publish([k, k], [b1, b2], [e1, e2], 16))
    assert idx.lookup(k).block_id == b2  # last occurrence wins
    assert idx.keys_of_blocks([b1, b2]) == [None, k]
    # evicting the orphaned first block must be a no-op, not destroy k
    assert idx.evict_blocks([b1]) == []
    assert idx.lookup(k) is not None
    assert idx.evict_blocks([b2]) == [b2]
    assert idx.lookup(k) is None


def test_wire_publish_rejects_out_of_range_block_ids():
    """Untrusted block ids must not scatter into block2row (negative ids
    would silently alias another block's owner pointer)."""
    pool, idx, chains = _published(1, 2)
    k = b"\x07" * 16
    for bad in (-1, pool.n_blocks, pool.n_blocks + 5):
        with pytest.raises(wire.WireError):
            wire.handle_request(idx, wire.encode_publish([k], [bad], [1], 16))
    assert idx.lookup(k) is None  # nothing was inserted
    # pre-existing entries untouched
    assert idx.keys_of_blocks(chains[0][2]) == list(chains[0][1])


def test_wire_reply_bound_rejects_before_mutation():
    """An op whose REPLY cannot fit the slot is refused up front — the
    index must not mutate server-side while the client only sees an
    error (e.g. an oversized EVICT silently freeing blocks)."""
    pool, idx, chains = _published(n_chains=1, chain_len=50)
    ring = ShmRing(n_slots=4, payload_bytes=128)
    server = CxlRpcServer(
        ring, wire.make_index_handler(idx, max_reply=ring.payload_bytes)
    ).start()
    try:
        client = CxlRpcClient(ring)
        entries_before = idx.stats()["entries"]
        with pytest.raises(RpcError):
            client.call(wire.encode_evict(1000))  # reply needs 8 KB
        assert idx.stats()["entries"] == entries_before  # NOT half-run
        # same guard for an EVICT smuggled through OP_BATCH (which the
        # proxy's per-op chunking does not cover)
        with pytest.raises(RpcError):
            client.call(wire.encode_batch([wire.encode_evict(1000)]))
        assert idx.stats()["entries"] == entries_before
        # a BATCH whose LATER sub-op is body-truncated must fail before
        # its leading mutating sub-op runs
        import struct as _struct

        bad_tail = _struct.pack("<BI", wire.OP_MATCH, 100)  # claims 100 keys
        with pytest.raises(RpcError):
            client.call(wire.encode_batch([wire.encode_evict(3), bad_tail]))
        assert idx.stats()["entries"] == entries_before
        # ... and the same for a SEMANTICALLY invalid later sub-op
        # (out-of-range publish): the batch starts clean or not at all
        bad_pub = wire.encode_publish([b"\x01" * 16], [10**6], [1], 16)
        with pytest.raises(RpcError):
            client.call(wire.encode_batch([wire.encode_evict(3), bad_pub]))
        assert idx.stats()["entries"] == entries_before
        # a fitting evict still works
        freed = wire.decode_evict_resp(client.call(wire.encode_evict(4)))
        assert len(freed) == 4
    finally:
        server.stop()


def test_wire_match_rejects_duplicate_keys():
    """Duplicate keys in one MATCH chain are invalid (chain hashes never
    repeat) and would corrupt the batch LRU splice — rejected up front."""
    _, idx, chains = _published(1, 4)
    k = chains[0][1][0]
    with pytest.raises(wire.WireError):
        wire.handle_request(idx, wire.encode_match([k, k]))
    # the LRU list is untouched: normal traffic still works
    assert len(idx.match_prefix(chains[0][0])) == 4
    assert idx.evict_lru(4) == chains[0][2]


def test_wire_batch_nesting_is_bounded():
    """A BATCH-of-BATCH bomb must fail as WireError, not RecursionError."""
    _, idx, chains = _published(1, 2)
    msg = wire.encode_match(chains[0][1])
    for _ in range(2000):
        msg = wire.encode_batch([msg])
    with pytest.raises(wire.WireError):
        wire.handle_request(idx, msg)
    # shallow nesting still works
    shallow = wire.encode_batch([wire.encode_batch([wire.encode_evict(0)])])
    wire.handle_request(idx, shallow)


@settings(max_examples=30, deadline=None)
@given(st.binary(min_size=0, max_size=200))
def test_wire_fuzz_never_crashes(blob):
    """Arbitrary bytes either decode to a valid op or raise WireError."""
    pool = _pool(64)
    idx = GlobalIndex(pool)
    try:
        wire.handle_request(idx, blob)
    except wire.WireError:
        pass


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 40),
    n_tokens=st.integers(1, 4096),
    seed=st.integers(0, 2**31),
)
def test_wire_publish_match_property(n, n_tokens, seed):
    """encode->handle->decode publish+match round-trips arbitrary rows."""
    rng = np.random.default_rng(seed)
    pool = _pool()
    idx = GlobalIndex(pool)
    keys = [rng.bytes(16) for _ in range(n)]
    blocks = pool.allocate(n)
    epochs = pool.write_blocks(blocks)
    wire.handle_request(idx, wire.encode_publish(keys, blocks, epochs, n_tokens))
    ids, eps = wire.decode_match_resp(
        wire.handle_request(idx, wire.encode_match(keys))
    )
    assert ids.tolist() == blocks and eps.tolist() == epochs


def test_wire_migration_ops_roundtrip():
    """OWNERS / REMAP / EVICT_BLOCKS — the migrator's control plane —
    behave over the codec exactly like the in-process index calls."""
    pool, idx, chains = _published(n_chains=1, chain_len=6)
    tokens, keys, blocks = chains[0]
    # OWNERS: only indexed blocks answer, input order, epochs attached
    [free] = pool.allocate(1)
    k, b, e = wire.decode_owners_resp(
        wire.handle_request(idx, wire.encode_owners(blocks[:3] + [free]))
    )
    assert (k, b) == (list(keys[:3]), blocks[:3])
    ref = idx.owners_of(blocks[:3] + [free])
    assert (k, b, e) == ref
    # REMAP: stale (old_id, old_epoch) loses the race, fresh one wins
    [nb] = pool.allocate(1)
    [ne] = pool.write_blocks([nb])
    ok = wire.decode_remap_resp(
        wire.handle_request(
            idx,
            wire.encode_remap(
                [keys[0], keys[1]], [blocks[0], blocks[1]], [e[0], 10**6],
                [nb, nb], [ne, ne],
            ),
        )
    )
    assert ok == [True, False]  # second had a wrong old epoch
    assert idx.lookup(keys[0]).block_id == nb
    assert idx.lookup(keys[1]).block_id == blocks[1]
    # EVICT_BLOCKS: frees exactly the indexed, unreferenced targets
    freed = wire.decode_evict_resp(
        wire.handle_request(idx, wire.encode_evict_blocks([nb, blocks[1], free]))
    )
    assert freed == [nb, blocks[1]]
    assert idx.lookup(keys[0]) is None and idx.lookup(keys[1]) is None


def test_wire_stats_op_roundtrip():
    """OP_STATS mirrors GlobalIndex.stats — the probe the cluster uses
    when the index lives in another process, and the occupancy signal of
    the sharded eviction policy."""
    pool, idx, chains = _published(n_chains=2, chain_len=5)
    idx.match_prefix(chains[0][0])
    idx.match_prefix(chains[1][0][: 3 * 16] + [-1] * 16)  # 3 hits + misses
    entries, hits, misses, ops, busy = wire.decode_stats_resp(
        wire.handle_request(idx, wire.encode_stats())
    )
    s = idx.stats()
    assert (entries, hits, misses) == (s["entries"], s["hits"], s["misses"])
    # service-side timer fields ride the same reply; without a ring ctrl
    # block wired in they read 0 (handle_request called directly here)
    assert (ops, busy) == (0, 0)
    assert wire.reply_bound(wire.encode_stats()) == 40
    # and over a live ring via the proxy (hit_rate computed client-side)
    ring = ShmRing(n_slots=2, payload_bytes=256)
    server = CxlRpcServer(ring, wire.make_index_handler(idx)).start()
    try:
        proxy = wire.RpcIndexClient(CxlRpcClient(ring), block_tokens=16)
        assert proxy.stats() == idx.stats()
        assert proxy.n_entries() == s["entries"]
    finally:
        server.stop()


def test_evict_never_rereleases_stale_rows():
    """Eviction-safety regression (found by the differential harness):
    a row whose block was already released — refcount 0, epoch bumped,
    possibly REALLOCATED to a new owner — must be GC'd by evict_lru /
    evict_blocks WITHOUT a second pool.release.  The old refcount<=1
    victim rule double-freed it (and against a reallocated block would
    have freed the new owner's live payload)."""
    pool, idx, chains = _published(n_chains=1, chain_len=6)
    tokens, keys, blocks = chains[0]
    pool.release([blocks[1], blocks[4]])  # stale rows, refcount 0
    free_before = pool.free_blocks()
    # evict_lru walks past the stale rows: they are dropped, not "freed"
    freed = idx.evict_lru(2)
    assert freed == [blocks[0], blocks[2]]  # live LRU victims only
    assert idx.lookup(keys[1]) is None  # stale row GC'd
    assert pool.free_blocks() == free_before + 2  # no double count
    # evict_blocks on a stale target: same rule
    assert idx.evict_blocks([blocks[4]]) == []
    assert idx.lookup(keys[4]) is None
    assert pool.free_blocks() == free_before + 2
    # a REALLOCATED block with a SURVIVING stale row must not be freed
    # out from under its new owner: publish a fresh key, release its
    # block (stale row, never walked), then reallocate that same block
    k = b"\x55" * 16
    [b] = pool.allocate(1)
    idx.publish(k, b, pool.write_blocks([b])[0], 16)
    pool.release([b])  # stale row for k survives, b back in the free pool
    got, held = [], []
    while b not in got:  # reacquire b (bounded: pool is finite)
        got = pool.allocate(1)
        held += got
    assert idx.lookup(k) is not None  # the stale row is still there
    assert idx.evict_blocks([b]) == []  # NOT freed under its new owner
    assert pool.refcounts[b] == 1  # new owner untouched
    assert idx.lookup(k) is None  # stale row GC'd instead
    pool.release(held)
    # on_evict (ghost arming) never fires for stale-row GC
    seen = []
    idx.on_evict = seen.append
    pool.release([blocks[5]])
    assert idx.evict_lru(10) == [blocks[3]]
    assert seen == [[keys[3]]]


def test_wire_migration_ops_reject_out_of_range_ids():
    pool, idx, chains = _published(1, 2)
    keys, blocks = chains[0][1], chains[0][2]
    bad = pool.n_blocks + 7
    for msg in (
        wire.encode_owners([bad]),
        wire.encode_evict_blocks([-1]),
        wire.encode_remap([keys[0]], [bad], [1], [blocks[0]], [1]),
        wire.encode_remap([keys[0]], [blocks[0]], [1], [-2], [1]),
    ):
        with pytest.raises(wire.WireError):
            wire.handle_request(idx, msg)
        with pytest.raises(wire.WireError):
            wire.prevalidate(idx, msg)
    # nothing mutated
    assert idx.keys_of_blocks(blocks) == list(keys)


# ---------------------------------------------------------------------------
# RpcIndexClient over a live ring
# ---------------------------------------------------------------------------


def test_rpc_index_client_matches_in_process_index():
    pool, idx, chains = _published(n_chains=2, chain_len=20)
    ring = ShmRing(n_slots=8, payload_bytes=4096)
    server = CxlRpcServer(ring, wire.make_index_handler(idx)).start()
    try:
        proxy = wire.RpcIndexClient(CxlRpcClient(ring), block_tokens=16)
        for tokens, keys, blocks in chains:
            assert proxy.match_prefix(tokens) == idx.match_prefix(tokens)
            assert proxy.filter_unpublished(keys) == []
            got = proxy.lookup_many(keys)
            assert [e.block_id for e in got] == blocks
        # divergent suffix matches the shared prefix only
        tokens = chains[0][0]
        assert len(proxy.match_prefix(tokens[:64] + [5] * 32)) == 4
    finally:
        server.stop()


def test_rpc_index_client_chunks_long_chains():
    """A chain longer than one ring slot splits without changing results."""
    pool, idx, chains = _published(n_chains=1, chain_len=40)
    tokens, keys, blocks = chains[0]
    ring = ShmRing(n_slots=4, payload_bytes=256)  # ~15 keys per slot
    server = CxlRpcServer(ring, wire.make_index_handler(idx)).start()
    try:
        proxy = wire.RpcIndexClient(CxlRpcClient(ring), block_tokens=16)
        assert proxy._max_match < len(keys)
        assert [b for _, b, _ in proxy.match_prefix(tokens)] == blocks
        pool.release([blocks[1]])  # early stale: later chunks must not run
        assert len(proxy.match_prefix(tokens)) == 1
        assert proxy.filter_unpublished(keys) == [1]
    finally:
        server.stop()


def test_rpc_index_client_chunks_evict_lru():
    """The EVICT response carries 8 B per freed id, so big evictions must
    split client-side instead of overflowing the reply slot."""
    pool, idx, chains = _published(n_chains=1, chain_len=60)
    ring = ShmRing(n_slots=4, payload_bytes=128)  # <= 14 ids per response
    server = CxlRpcServer(ring, wire.make_index_handler(idx)).start()
    try:
        proxy = wire.RpcIndexClient(CxlRpcClient(ring), block_tokens=16)
        assert proxy._max_evict < 60
        freed = proxy.evict_lru(60)
        assert sorted(freed) == sorted(chains[0][2])
        assert idx.stats()["entries"] == 0
    finally:
        server.stop()


def test_server_survives_handler_failure():
    """A malformed frame (or any handler exception) comes back as an
    in-band RpcError; the metadata service thread keeps serving."""
    pool, idx, chains = _published(1, 4)
    ring = ShmRing(n_slots=4, payload_bytes=1024)
    server = CxlRpcServer(ring, wire.make_index_handler(idx)).start()
    try:
        client = CxlRpcClient(ring)
        proxy = wire.RpcIndexClient(client, block_tokens=16)
        with pytest.raises(RpcError):
            client.call(wire.encode_match(chains[0][1])[:10])  # truncated
        assert server._thread.is_alive()
        # well-formed traffic flows normally afterwards
        assert len(proxy.match_prefix(chains[0][0])) == 4
        assert client.free_slots() == ring.n_slots
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# RPC error accounting + in-band error frames
# ---------------------------------------------------------------------------


def test_rpc_stats_account_failed_round_trips():
    """RESP_ERROR and timeouts must be VISIBLE in RpcStats: counted, and
    their wait time folded into total_wait (the old client raised before
    touching the stats, so error-heavy runs looked like rosy QD=1 runs
    over the successes only)."""
    gate = threading.Event()

    def handler(payload: bytes) -> bytes:
        if payload == b"hang":
            gate.wait(5)
            return b"late"
        if payload == b"boom":
            raise ValueError("no")
        return payload

    ring = ShmRing(n_slots=2, payload_bytes=64)
    server = CxlRpcServer(ring, handler).start()
    try:
        client = CxlRpcClient(ring)
        client.call(b"fine")
        with pytest.raises(RpcError):
            client.call(b"boom")
        wait_after_error = client.stats.total_wait
        with pytest.raises(TimeoutError):
            client.call(b"hang", timeout=0.05)
        s = client.stats
        assert (s.requests, s.errors, s.timeouts) == (1, 1, 1)
        assert s.round_trips == 3
        # the timeout contributed >= its 50 ms deadline of wait
        assert s.total_wait >= wait_after_error + 0.05
        assert s.avg_wait() == s.total_wait / 3
    finally:
        gate.set()
        server.stop()


def test_error_frame_truncates_on_utf8_character_boundary():
    """A long non-ASCII handler error must be cut on a CHARACTER boundary
    when it exceeds the slot: the byte-slice truncation could split a
    multi-byte UTF-8 sequence and ship mojibake to the caller."""
    boom = "кэш-блок недействителен: " + "デ" * 40  # >64 B encoded

    def handler(payload: bytes) -> bytes:
        raise RuntimeError(boom)

    ring = ShmRing(n_slots=1, payload_bytes=64)
    assert len(f"RuntimeError: {boom}".encode()) > ring.payload_bytes
    server = CxlRpcServer(ring, handler).start()
    try:
        client = CxlRpcClient(ring)
        with pytest.raises(RpcError) as ei:
            client.call(b"x")
        msg = str(ei.value)
        assert "�" not in msg  # decoded cleanly: no replacement char
        assert msg.startswith("RuntimeError: кэш-блок")
        assert len(msg.encode()) <= ring.payload_bytes
        # a whole number of characters survived the cut
        full = f"RuntimeError: {boom}"
        assert full.startswith(msg)
    finally:
        server.stop()


def test_post_collect_split_round_trip():
    """post() keeps several requests outstanding; collect() in any order."""
    ring = ShmRing(n_slots=4, payload_bytes=64)
    server = CxlRpcServer(ring, lambda p: b"ok:" + p).start()
    try:
        client = CxlRpcClient(ring)
        slots = [client.post(bytes([65 + i]) * 4) for i in range(3)]
        outs = [client.collect(s) for s in reversed(slots)]
        assert outs == [b"ok:CCCC", b"ok:BBBB", b"ok:AAAA"]
        assert client.free_slots() == 4
        assert client.stats.requests == 3
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# ring hardening: timeout quarantine + slot exhaustion
# ---------------------------------------------------------------------------


def test_timeout_quarantines_slot_until_server_responds():
    ring = ShmRing(n_slots=1, payload_bytes=64)
    release = threading.Event()

    def slow_handler(payload: bytes) -> bytes:
        release.wait(5)
        return b"LATE:" + payload

    server = CxlRpcServer(ring, slow_handler).start()
    try:
        client = CxlRpcClient(ring)
        with pytest.raises(TimeoutError):
            client.call(b"victim", timeout=0.05)
        assert client.stats.timeouts == 1
        # the slot is NOT back on the free list: the only slot is
        # quarantined, so the next call reports exhaustion instead of
        # reusing a slot the server may still write into
        assert client.free_slots() == 0
        with pytest.raises(RuntimeError):
            client.call(b"second")
        # server finally answers the stale request
        release.set()
        deadline = time.time() + 5
        while ring.status[0] != RESP_READY and time.time() < deadline:
            time.sleep(0.01)
        # next acquire reclaims the slot and the late response is
        # dropped, never handed to the new caller
        out = client.call(b"fresh", timeout=5)
        assert out == b"LATE:fresh"
        assert client.free_slots() == 1
    finally:
        release.set()
        server.stop()


def test_concurrent_clients_slot_exhaustion_and_recovery():
    ring = ShmRing(n_slots=2, payload_bytes=64)
    gate = threading.Event()

    def handler(payload: bytes) -> bytes:
        if payload.startswith(b"block"):
            gate.wait(5)
        return bytes((x + 1) % 256 for x in payload)

    server = CxlRpcServer(ring, handler).start()
    try:
        client = CxlRpcClient(ring)
        errors, oks = [], []

        def blocked():
            try:
                oks.append(client.call(b"block", timeout=5))
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        ts = [threading.Thread(target=blocked) for _ in range(2)]
        for t in ts:
            t.start()
        deadline = time.time() + 5
        while client.free_slots() > 0 and time.time() < deadline:
            time.sleep(0.01)
        # both slots in flight: an extra caller is rejected, not corrupted
        with pytest.raises(RuntimeError):
            client.call(b"extra")
        gate.set()
        for t in ts:
            t.join()
        assert not errors and len(oks) == 2
        # ring fully recovered: responses flow again with correct payloads
        for i in range(8):
            payload = bytes([i]) * 8
            assert client.call(payload) == bytes((x + 1) % 256 for x in payload)
        assert client.free_slots() == 2
    finally:
        gate.set()
        server.stop()


# ---------------------------------------------------------------------------
# keys_for aliasing (shared cached chain) — regression
# ---------------------------------------------------------------------------


def test_keys_for_shared_cache_is_immutable_and_mutation_safe():
    h = PrefixHasher(16)
    tokens = list(range(160))
    first = h.keys_for(tokens)
    assert isinstance(first, tuple)  # structurally immutable: no aliasing bug
    assert h.keys_for(list(tokens)) is first  # shared cached chain
    with pytest.raises(TypeError):
        first[0] = b"boom"  # type: ignore[index]
    # mutating the CALLER's list must not poison the memo for other users
    tokens[32] = -7
    mutated = h.keys_for(tokens)
    assert mutated is not first
    assert mutated[:2] == first[:2] and mutated[2] != first[2]
    assert h.keys_for(list(range(160))) == first


def test_cluster_index_rpc_mode_end_to_end():
    from repro.serving.request import Request
    from repro.serving.scheduler import Cluster, ClusterConfig

    c = Cluster(
        ClusterConfig(
            n_engines=2, pool_blocks=2048, hbm_slots_per_engine=256,
            index_rpc=True, index_rpc_slots=8,
        ),
        LAYOUT,
    )
    try:
        base = list(range(512))
        for i in range(8):
            c.dispatch(Request(f"r{i}", base, 8, 0.0))
        s1 = c.run()
        assert s1["n_done"] == 8
        assert s1["index"]["hits"] > 0  # ops really reached the index
        assert c._rpc_client.stats.requests > 0  # ... over the ring
        t0 = max(e.clock for e in c.engines)
        tail = [Request(f"h{i}", base, 8, t0) for i in range(4)]
        for r in tail:
            c.dispatch(r)
        c.run()
        assert all(r.hit_tokens > 0 for r in tail)  # pool hits via RPC
    finally:
        c.close()


# ---------------------------------------------------------------------------
# flat-array index internals
# ---------------------------------------------------------------------------


def test_index_lru_order_tracks_matches():
    pool, idx, chains = _published(n_chains=3, chain_len=4)
    # touch chains 2 then 0; chain 1 becomes LRU
    idx.match_prefix(chains[2][0])
    idx.match_prefix(chains[0][0])
    freed = idx.evict_lru(4)
    assert sorted(freed) == sorted(chains[1][2])
    assert len(idx.match_prefix(chains[1][0])) == 0
    assert len(idx.match_prefix(chains[0][0])) == 4
    assert len(idx.match_prefix(chains[2][0])) == 4


def test_index_grows_past_initial_capacity():
    pool = BelugaPool(LAYOUT, n_blocks=8192, n_shards=8, backing="meta")
    idx = GlobalIndex(pool)
    tokens = list(range(5000 * 16))  # 5000 rows > initial 1024 capacity
    keys = idx.keys_for(tokens)
    blocks = pool.allocate(len(keys))
    idx.publish_many(keys, blocks, pool.write_blocks(blocks), 16)
    assert idx.stats()["entries"] == 5000
    hits = idx.match_prefix(tokens)
    assert [b for _, b, _ in hits] == blocks


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=30), st.integers(1, 6))
def test_index_lru_eviction_matches_ordered_dict_model(touch_order, n_evict):
    """Eviction order of the array-intrusive LRU == an OrderedDict model
    under an arbitrary interleaving of matches (the batch-splice path)."""
    from collections import OrderedDict

    pool, idx, chains = _published(n_chains=6, chain_len=3)
    model: OrderedDict[int, None] = OrderedDict((d, None) for d in range(6))
    for d in touch_order:
        assert len(idx.match_prefix(chains[d][0])) == 3
        model.move_to_end(d)
    freed = idx.evict_lru(3 * n_evict)
    want: list[int] = []
    for d in list(model)[:n_evict]:
        want.extend(chains[d][2])
    assert freed == want


# ---------------------------------------------------------------------------
# codec exhaustiveness: every OP_* in the registry round-trips (PR 9)
# ---------------------------------------------------------------------------
def _wire_registry() -> dict[str, int]:
    return {
        name: val
        for name, val in vars(wire).items()
        if name.startswith("OP_") and isinstance(val, int)
    }


def test_wire_registry_values_are_unique_and_dense():
    ops = _wire_registry()
    vals = sorted(ops.values())
    assert len(set(vals)) == len(vals), "duplicate opcode values"
    assert vals == list(range(1, len(vals) + 1)), "opcode space has holes"


def test_every_opcode_round_trips_with_boundary_payloads():
    """Exhaustiveness is DERIVED, not hand-maintained: the table below is
    keyed by ``OP_*`` name and the test fails outright if the module's
    registry grows an opcode the table doesn't exercise (the runtime
    companion of the ``wire_protocol`` lint pass).  Each op ships at
    least an empty/zero frame and a populated frame; every reply must
    fit its declared ``reply_bound`` and decode cleanly."""
    from repro.core.shm import ShardJournal

    pool, idx, chains = _published(n_chains=2, chain_len=4)
    tokens, keys, blocks = chains[0]
    keys = list(keys)  # keys_for returns an immutable (cached) tuple
    eps = [idx.lookup_many(keys)[i].epoch for i in range(len(keys))]
    fresh = pool.allocate(len(keys))
    fresh_eps = pool.write_blocks(fresh)
    spare = pool.allocate(4)
    jrnl = ShardJournal.create(capacity=64)
    jkeys = [bytes([i]) * wire.KEY_BYTES for i in range(3)]

    def index_route(frame: bytes) -> tuple[bytes, int]:
        bound = wire.reply_bound(frame)
        wire.prevalidate(idx, frame)
        return wire.handle_request(idx, frame, _validated=True), bound

    def pool_route(frame: bytes) -> tuple[bytes, int]:
        return wire.handle_pool_request(pool, frame), wire.pool_reply_bound(frame)

    # the keyed-alloc / touch ops only exist on tiered parents
    from repro.tiering.tiers import TieredPool, TieringConfig

    tpool = TieredPool(
        LAYOUT, fast_blocks=16, spill_blocks=16, n_shards=4,
        backing="meta", cfg=TieringConfig(enabled=True),
    )
    tblocks = tpool.allocate(4)

    def tiered_route(frame: bytes) -> tuple[bytes, int]:
        return (
            wire.handle_pool_request(tpool, frame),
            wire.pool_reply_bound(frame),
        )

    def jrnl_route(frame: bytes) -> tuple[bytes, int]:
        return (
            wire.handle_journal_request(frame, [jrnl]),
            wire.pool_reply_bound(frame),
        )

    def u32_resp(buf: bytes):
        assert len(buf) == 4
        return buf

    # OP name -> (route, decoder, [boundary frames])
    table = {
        "OP_MATCH": (index_route, wire.decode_match_resp, [
            wire.encode_match([]),
            wire.encode_match(keys),
        ]),
        "OP_PUBLISH": (index_route, wire.decode_publish_resp, [
            wire.encode_publish([], [], [], 0),
            wire.encode_publish(keys, blocks, eps, 16),
        ]),
        "OP_LOOKUP": (index_route, wire.decode_lookup_resp, [
            wire.encode_lookup([]),
            wire.encode_lookup(keys + [b"\xff" * wire.KEY_BYTES]),
        ]),
        "OP_FILTER": (index_route, wire.decode_filter_resp, [
            wire.encode_filter([]),
            wire.encode_filter(keys + [b"\xfe" * wire.KEY_BYTES]),
        ]),
        "OP_EVICT": (index_route, wire.decode_evict_resp, [
            wire.encode_evict(0),
            wire.encode_evict(2),
        ]),
        "OP_BATCH": (index_route, wire.decode_batch_resp, [
            wire.encode_batch([]),
            wire.encode_batch([wire.encode_stats(), wire.encode_match(keys)]),
        ]),
        "OP_OWNERS": (index_route, wire.decode_owners_resp, [
            wire.encode_owners([]),
            wire.encode_owners(blocks + spare),  # spare: unindexed ids
        ]),
        "OP_REMAP": (index_route, wire.decode_remap_resp, [
            wire.encode_remap([], [], [], [], []),
            wire.encode_remap(keys, blocks, eps, fresh, fresh_eps),
        ]),
        "OP_EVICT_BLOCKS": (index_route, wire.decode_evict_resp, [
            wire.encode_evict_blocks([]),
            wire.encode_evict_blocks(spare),  # in range, nothing to evict
        ]),
        "OP_STATS": (index_route, wire.decode_stats_resp, [
            wire.encode_stats(),
        ]),
        "OP_SNAPSHOT": (index_route, wire.decode_snapshot_resp, [
            wire.encode_snapshot(0, 0),
            wire.encode_snapshot(0, 64),
        ]),
        "OP_RESTORE": (index_route, wire.decode_restore_resp, [
            wire.encode_restore([], [], [], []),
            wire.encode_restore(keys, blocks, eps, [16] * len(keys)),
        ]),
        "OP_SEED_STATS": (index_route, u32_resp, [
            wire.encode_seed_stats(0, 0),
            wire.encode_seed_stats(2**40, 2**40),
        ]),
        "OP_POOL_ALLOC": (pool_route, wire.decode_pool_alloc_resp, [
            wire.encode_pool_alloc(0),
            wire.encode_pool_alloc(8),
        ]),
        "OP_POOL_RETAIN": (pool_route, u32_resp, [
            wire.encode_pool_retain([]),
            # published blocks: live refs regardless of table order
            # (OP_POOL_RELEASE sorts earlier and frees `spare`)
            wire.encode_pool_retain(blocks),
        ]),
        "OP_POOL_RELEASE": (pool_route, u32_resp, [
            wire.encode_pool_release([]),
            wire.encode_pool_release(spare),
        ]),
        "OP_POOL_FREE": (pool_route, wire.decode_pool_free_resp, [
            wire.encode_pool_free(),
        ]),
        "OP_POOL_ALLOC_KEYS": (tiered_route, wire.decode_pool_alloc_resp, [
            wire.encode_pool_alloc_keys([]),
            wire.encode_pool_alloc_keys(jkeys),
        ]),
        "OP_POOL_TOUCH": (tiered_route, wire.decode_pool_touch_resp, [
            wire.encode_pool_touch([], 0.0),
            wire.encode_pool_touch(tblocks, 1.0),
        ]),
        "OP_JRNL_PUBLISH": (jrnl_route, u32_resp, [
            wire.encode_jrnl_publish(0, [], [], [], 0),
            wire.encode_jrnl_publish(0, jkeys, [1, 2, 3], [7, 7, 7], 16),
        ]),
        "OP_JRNL_RETRACT": (jrnl_route, u32_resp, [
            wire.encode_jrnl_retract(0, []),
            wire.encode_jrnl_retract(0, [1, 2, 3]),
        ]),
        "OP_JRNL_REMAP": (jrnl_route, u32_resp, [
            wire.encode_jrnl_remap(0, [], [], []),
            wire.encode_jrnl_remap(0, jkeys, [4, 5, 6], [8, 8, 8]),
        ]),
    }

    try:
        registry = _wire_registry()
        missing = set(registry) - set(table)
        stale = set(table) - set(registry)
        assert not missing, f"opcodes without codec coverage: {sorted(missing)}"
        assert not stale, f"table entries for removed opcodes: {sorted(stale)}"

        for name, (route, decoder, frames) in sorted(table.items()):
            assert frames, f"{name}: no boundary frames"
            for frame in frames:
                assert frame[0] == registry[name], f"{name}: wrong op byte"
                reply, bound = route(frame)
                assert len(reply) <= bound, (
                    f"{name}: reply {len(reply)} B exceeds bound {bound} B"
                )
                decoder(reply)  # must decode without raising
    finally:
        jrnl.close()

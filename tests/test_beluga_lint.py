"""beluga-lint: the linter's own test suite (static-analysis PR).

Two layers:

  * acceptance — the merged tree is CLEAN (zero findings over ``src/``),
    and each seeded mutation of a REAL source file is caught by the pass
    that owns the invariant (the four mutation classes from the issue:
    unhandled opcode, attach-side unlink, inverted lock pair, swallowed
    exception);
  * unit — each rule fires on a minimal synthetic module and stays quiet
    on the conforming variant, plus the CLI surface (baselines,
    --check-lock-log, exit codes).
"""

from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from tools.beluga_lint import PASSES, load_all_passes  # noqa: E402
from tools.beluga_lint.__main__ import main as lint_main  # noqa: E402
from tools.beluga_lint.passes import lock_discipline  # noqa: E402
from tools.beluga_lint.project import Project  # noqa: E402

load_all_passes()


def run_pass(name: str, paths: list[str]):
    return PASSES[name].run(Project.load(paths))


def run_all(paths: list[str]):
    project = Project.load(paths)
    out = []
    for name in sorted(PASSES):
        out.extend(PASSES[name].run(project))
    return out


def write(tmp_path, name: str, source: str) -> str:
    p = tmp_path / name
    p.write_text(source)
    return str(p)


# ---------------------------------------------------------------------------
# acceptance: clean tree, dirty mutants
# ---------------------------------------------------------------------------
def test_merged_tree_is_clean():
    findings = run_all([SRC])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_exits_zero_on_clean_tree(capsys):
    assert lint_main([SRC]) == 0
    assert "clean" in capsys.readouterr().out


@pytest.fixture()
def mutant_tree(tmp_path):
    """A copy of the real core sources that mutations are applied to."""
    root = tmp_path / "src"
    shutil.copytree(os.path.join(SRC, "repro"), root / "repro")
    return root


def test_mutation_unhandled_opcode_is_caught(mutant_tree):
    wire = mutant_tree / "repro" / "core" / "wire.py"
    wire.write_text(wire.read_text() + "\nOP_SHINY = 21\n")
    rules = {f.rule for f in run_pass("wire_protocol", [str(mutant_tree)])}
    # no handler branch, no reply bound, no encoder
    assert {"W002", "W003", "W005"} <= rules


def test_mutation_attach_side_unlink_is_caught(mutant_tree):
    sp = mutant_tree / "repro" / "core" / "shmpool.py"
    sp.write_text(sp.read_text().replace(
        "close_segment(self._data_segment, unlink=False)",
        "close_segment(self._data_segment, unlink=True)",
    ))
    findings = run_pass("shm_lifecycle", [str(mutant_tree)])
    assert any(f.rule == "S002" for f in findings)


def test_mutation_inverted_lock_pair_is_caught(mutant_tree):
    # the real tree orders index._lock -> pool._lock (evict_lru); a new
    # code path nesting them the other way must trip the cycle detector
    pool = mutant_tree / "repro" / "core" / "pool.py"
    pool.write_text(pool.read_text() + '''

def _mutant_reverse(pool: "BelugaPool", index: "GlobalIndex") -> None:
    with pool._lock:
        with index._lock:
            pass
''')
    findings = run_pass("lock_discipline", [str(mutant_tree)])
    cycles = [f for f in findings if f.rule == "L002"]
    assert cycles and "index.GlobalIndex._lock" in cycles[0].message


def test_mutation_swallowed_exception_is_caught(mutant_tree):
    shm = mutant_tree / "repro" / "core" / "shm.py"
    shm.write_text(shm.read_text() + '''

def _mutant_swallow():
    try:
        raise ValueError("x")
    except Exception:
        pass
''')
    findings = run_pass("exception_hygiene", [str(mutant_tree)])
    assert any(f.rule == "E001" for f in findings)


# ---------------------------------------------------------------------------
# wire_protocol units
# ---------------------------------------------------------------------------
WIRE_OK = """
OP_A = 1
OP_B = 2

def encode_a(keys):
    return bytes([OP_A])

def encode_b(ids):
    return bytes([OP_B])

def reply_bound(buf):
    op = buf[0]
    if op == OP_A:
        return 4
    if op == OP_B:
        return 8
    raise ValueError(op)

def prevalidate(index, buf):
    op = buf[0]
    if op == OP_B:
        pass

def handle_request(index, buf):
    op = buf[0]
    if op == OP_A:
        return b"a"
    if op == OP_B:
        return b"b"
    raise ValueError(op)
"""


def test_wire_clean_module_passes(tmp_path):
    write(tmp_path, "wire.py", WIRE_OK)
    assert run_pass("wire_protocol", [str(tmp_path)]) == []


def test_wire_duplicate_value(tmp_path):
    write(tmp_path, "wire.py", WIRE_OK.replace("OP_B = 2", "OP_B = 1"))
    assert any(
        f.rule == "W001"
        for f in run_pass("wire_protocol", [str(tmp_path)])
    )


def test_wire_ids_op_missing_prevalidate(tmp_path):
    src = WIRE_OK.replace("    if op == OP_B:\n        pass\n", "    pass\n")
    write(tmp_path, "wire.py", src)
    findings = run_pass("wire_protocol", [str(tmp_path)])
    assert [f.rule for f in findings] == ["W004"]


def test_wire_literal_opcode_comparison(tmp_path):
    src = WIRE_OK.replace("if op == OP_A:\n        return b\"a\"",
                          "if op == 1:\n        return b\"a\"")
    write(tmp_path, "wire.py", src)
    rules = {f.rule for f in run_pass("wire_protocol", [str(tmp_path)])}
    assert "W006" in rules


def test_wire_wcmd_registry(tmp_path):
    write(tmp_path, "eng.py", """
WCMD_X, WCMD_Y = 1, 2

def serve(cmd, hdr):
    if cmd == WCMD_X:
        return 1

def post(hdr):
    return hdr.pack(WCMD_X, 0)
""")
    rules = {f.rule for f in run_pass("wire_protocol", [str(tmp_path)])}
    assert rules == {"W007", "W008"}  # WCMD_Y neither handled nor packed


# ---------------------------------------------------------------------------
# shm_lifecycle units
# ---------------------------------------------------------------------------
def test_shm_missing_unlink_kwarg(tmp_path):
    write(tmp_path, "m.py", """
from repro.core.shm import close_segment

def teardown(seg):
    close_segment(seg)
""")
    findings = run_pass("shm_lifecycle", [str(tmp_path)])
    assert [f.rule for f in findings] == ["S001"]


def test_shm_discarded_create_handle(tmp_path):
    write(tmp_path, "m.py", """
from repro.core.shm import create_segment

def boot():
    create_segment(64)
""")
    rules = [f.rule for f in run_pass("shm_lifecycle", [str(tmp_path)])]
    assert "S004" in rules


def test_shm_creator_attr_without_teardown(tmp_path):
    write(tmp_path, "m.py", """
from repro.core.shm import create_segment

class Holder:
    def __init__(self):
        self._seg = create_segment(64)
""")
    findings = run_pass("shm_lifecycle", [str(tmp_path)])
    assert [f.rule for f in findings] == ["S005"]
    assert "Holder._seg" in findings[0].message


def test_shm_creator_attr_with_teardown_is_clean(tmp_path):
    write(tmp_path, "m.py", """
from repro.core.shm import close_segment, create_segment

class Holder:
    def __init__(self):
        self._seg = create_segment(64)

    def close(self):
        close_segment(self._seg, unlink=True)
""")
    assert run_pass("shm_lifecycle", [str(tmp_path)]) == []


def test_shm_classmethod_constructor_flow_is_tracked(tmp_path):
    write(tmp_path, "m.py", """
from repro.core.shm import create_segment

class Ring:
    def __init__(self, seg):
        self._seg = seg

    @classmethod
    def create(cls):
        seg = create_segment(64)
        return cls(seg)
""")
    findings = run_pass("shm_lifecycle", [str(tmp_path)])
    assert [f.rule for f in findings] == ["S005"]


def test_shm_local_leak(tmp_path):
    write(tmp_path, "m.py", """
from repro.core.shm import create_segment

def boot():
    seg = create_segment(64)
    return None
""")
    findings = run_pass("shm_lifecycle", [str(tmp_path)])
    assert [f.rule for f in findings] == ["S005"]


def test_shm_raw_unlink_outside_close_segment(tmp_path):
    write(tmp_path, "m.py", """
def teardown(seg):
    seg.unlink()
""")
    findings = run_pass("shm_lifecycle", [str(tmp_path)])
    assert [f.rule for f in findings] == ["S003"]


# ---------------------------------------------------------------------------
# lock_discipline units
# ---------------------------------------------------------------------------
def test_lock_raw_threading_lock(tmp_path):
    write(tmp_path, "m.py", """
import threading

class C:
    def __init__(self):
        self._lock = threading.Lock()
""")
    findings = run_pass("lock_discipline", [str(tmp_path)])
    assert [f.rule for f in findings] == ["L001"]


def test_lock_blocking_call_under_strict_lock(tmp_path):
    write(tmp_path, "m.py", """
import time
from repro.core.locks import make_lock

class C:
    def __init__(self):
        self._lock = make_lock("m.C._lock")

    def step(self):
        with self._lock:
            time.sleep(0.5)
""")
    findings = run_pass("lock_discipline", [str(tmp_path)])
    assert [f.rule for f in findings] == ["L003"]


def test_lock_blocking_ok_declaration_permits_blocking(tmp_path):
    write(tmp_path, "m.py", """
import time
from repro.core.locks import make_lock

class C:
    def __init__(self):
        self._lock = make_lock("m.C._lock", blocking_ok=True)

    def step(self):
        with self._lock:
            time.sleep(0.5)
""")
    assert run_pass("lock_discipline", [str(tmp_path)]) == []


def test_lock_sleep_zero_is_a_yield_not_blocking(tmp_path):
    write(tmp_path, "m.py", """
import time
from repro.core.locks import make_lock

class C:
    def __init__(self):
        self._lock = make_lock("m.C._lock")

    def step(self):
        with self._lock:
            time.sleep(0)
""")
    assert run_pass("lock_discipline", [str(tmp_path)]) == []


def test_lock_transitive_blocking_through_callee(tmp_path):
    write(tmp_path, "m.py", """
import time
from repro.core.locks import make_lock

class C:
    def __init__(self):
        self._lock = make_lock("m.C._lock")

    def _slow(self):
        time.sleep(1.0)

    def step(self):
        with self._lock:
            self._slow()
""")
    findings = run_pass("lock_discipline", [str(tmp_path)])
    assert [f.rule for f in findings] == ["L003"]
    assert "reaches blocking 'sleep'" in findings[0].message


def test_lock_cycle_detected_across_classes(tmp_path):
    write(tmp_path, "m.py", """
from repro.core.locks import make_lock

class A:
    def __init__(self, b: "B"):
        self._lock = make_lock("m.A._lock")
        self.b = b

    def fwd(self):
        with self._lock:
            with self.b._lock:
                pass

class B:
    def __init__(self, a: "A"):
        self._lock = make_lock("m.B._lock")
        self.a = a

    def rev(self):
        with self._lock:
            with self.a._lock:
                pass
""")
    findings = run_pass("lock_discipline", [str(tmp_path)])
    assert any(f.rule == "L002" for f in findings)


def test_lock_graph_matches_known_topology():
    decls, edges, findings = lock_discipline.build(Project.load([SRC]))
    assert findings == []
    names = {d.name for d in decls}
    # every make_lock declaration in the tree is seen
    assert {
        "pool.BelugaPool._lock",
        "index.GlobalIndex._lock",
        "rpc.CxlRpcClient._slot_lock",
        "shm.ShardJournal._lock",
        "shmpool.WorkerLeaseLedger.mutex",
        "scheduler.Cluster._meta_lock",
        "procserver.ShardSupervisor._lock",
        "engineproc.EngineWorkerSupervisor._lock",
        "seed_baseline.SeedPool._lock",
    } <= names
    # the load-bearing edges of the plane
    assert ("index.GlobalIndex._lock", "pool.BelugaPool._lock") in edges
    assert ("shmpool.WorkerLeaseLedger.mutex", "pool.BelugaPool._lock") in edges
    assert (
        "scheduler.Cluster._meta_lock",
        "shmpool.WorkerLeaseLedger.mutex",
    ) in edges
    assert lock_discipline.find_cycle(edges) is None


# ---------------------------------------------------------------------------
# exception_hygiene units
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("body,clean", [
    ("pass", False),
    ("return None", False),
    ("raise", True),
    ("x = 1\nraise RuntimeError('boom')", True),
    ("print(e)", True),
    ("stats.errors += 1", True),
    ("diag.note('m.fallback')", True),
    ("log.warning('fallback')", True),
])
def test_exception_hygiene_classification(tmp_path, body, clean):
    indented = "\n".join("        " + line for line in body.splitlines())
    write(tmp_path, "m.py", f"""
def f(stats, diag, log):
    try:
        work()
    except Exception as e:
{indented}
""")
    findings = run_pass("exception_hygiene", [str(tmp_path)])
    # "pass"/"return" bodies never reference e -> E001; the rest do leave
    # a trace (note the bare 'print(e)' case references the bound var too)
    assert (findings == []) == clean


def test_exception_hygiene_specific_types_exempt(tmp_path):
    write(tmp_path, "m.py", """
def f():
    try:
        work()
    except OSError:
        pass
    except (ValueError, KeyError):
        pass
""")
    assert run_pass("exception_hygiene", [str(tmp_path)]) == []


# ---------------------------------------------------------------------------
# CLI: baselines, lock-log checking, JSON output
# ---------------------------------------------------------------------------
def test_baseline_suppresses_known_finding(tmp_path, capsys):
    bad = tmp_path / "scan"
    bad.mkdir()
    (bad / "m.py").write_text("""
def f():
    try:
        work()
    except Exception:
        pass
""")
    bdir = tmp_path / "baselines"
    assert lint_main([str(bad), "--baseline-dir", str(bdir)]) == 1
    assert lint_main([
        str(bad), "--baseline-dir", str(bdir), "--update-baselines",
    ]) == 0
    capsys.readouterr()
    assert lint_main([str(bad), "--baseline-dir", str(bdir)]) == 0
    assert "1 baselined" in capsys.readouterr().out


def test_shipped_baselines_are_empty():
    bdir = os.path.join(REPO, "tools", "beluga_lint", "baselines")
    for name in os.listdir(bdir):
        if not name.endswith(".txt"):
            continue
        with open(os.path.join(bdir, name)) as f:
            lines = [
                ln for ln in f
                if ln.strip() and not ln.strip().startswith("#")
            ]
        assert lines == [], f"baseline {name} must ship empty"


def test_json_output_shape(tmp_path, capsys):
    bad = tmp_path / "scan"
    bad.mkdir()
    (bad / "m.py").write_text("""
def f():
    try:
        work()
    except Exception:
        pass
""")
    assert lint_main([str(bad), "--json"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["findings"][0]["rule"] == "E001"
    assert payload["findings"][0]["pass"] == "exception_hygiene"


def test_check_lock_log_consistent_and_inverted(tmp_path, capsys):
    log_dir = tmp_path / "logs"
    log_dir.mkdir()
    # consistent: a runtime observation of the static evict_lru edge
    (log_dir / "lock_order.1.json").write_text(json.dumps({
        "pid": 1,
        "edges": [["index.GlobalIndex._lock", "pool.BelugaPool._lock"]],
        "violations": [],
    }))
    assert lint_main([SRC, "--check-lock-log", str(log_dir)]) == 0
    capsys.readouterr()
    # inverted: runtime saw pool -> index, static graph has index -> pool
    (log_dir / "lock_order.2.json").write_text(json.dumps({
        "pid": 2,
        "edges": [["pool.BelugaPool._lock", "index.GlobalIndex._lock"]],
        "violations": [],
    }))
    assert lint_main([SRC, "--check-lock-log", str(log_dir)]) == 1
    assert "cycle" in capsys.readouterr().out


def test_check_lock_log_flags_undeclared_runtime_lock(tmp_path):
    log_dir = tmp_path / "logs"
    log_dir.mkdir()
    (log_dir / "lock_order.9.json").write_text(json.dumps({
        "pid": 9,
        "edges": [["phantom.Lock", "pool.BelugaPool._lock"]],
        "violations": [],
    }))
    assert lint_main([SRC, "--check-lock-log", str(log_dir)]) == 1


def test_cli_list_names_all_passes(capsys):
    assert lint_main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in (
        "wire_protocol", "shm_lifecycle", "lock_discipline",
        "exception_hygiene",
    ):
        assert name in out


def test_cli_module_entrypoint_runs():
    # the documented invocation shape, end to end as a subprocess
    proc = subprocess.run(
        [sys.executable, "-m", "tools.beluga_lint", "src", "--json"],
        cwd=REPO, capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert json.loads(proc.stdout)["findings"] == []

"""Per-kernel allclose sweeps (Pallas interpret=True vs pure-jnp oracles)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


RNG = np.random.default_rng(0)


def _randn(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# flash attention: shapes x dtypes sweep
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    # (b, sq, skv, hq, hkv, d)
    (1, 64, 64, 4, 4, 64),      # MHA
    (2, 128, 128, 8, 2, 64),    # GQA 4:1
    (1, 96, 96, 4, 1, 128),     # MQA, ragged seq
    (2, 128, 128, 16, 16, 128), # olmo-like head ratio
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(shape, dtype):
    b, sq, skv, hq, hkv, d = shape
    q = _randn((b, sq, hq, d), dtype)
    k = _randn((b, skv, hkv, d), dtype)
    v = _randn((b, skv, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, mode="pallas",
                              block_q=32, block_kv=32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_noncausal():
    q = _randn((1, 64, 4, 64), jnp.float32)
    k = _randn((1, 64, 4, 64), jnp.float32)
    v = _randn((1, 64, 4, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, mode="pallas",
                              block_q=32, block_kv=32)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

PAGED_SHAPES = [
    # (b, hq, hkv, d, bt, max_blocks, n_blocks)
    (3, 8, 2, 64, 16, 6, 32),
    (2, 4, 4, 128, 16, 4, 16),
    (1, 16, 8, 64, 32, 3, 8),
]


@pytest.mark.parametrize("shape", PAGED_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_oracle(shape, dtype):
    b, hq, hkv, d, bt, mb, nb = shape
    q = _randn((b, hq, d), dtype)
    pool = _randn((nb, 2, bt, hkv, d), dtype)
    tbl = jnp.asarray(
        np.stack([RNG.choice(nb, size=mb, replace=False) for _ in range(b)]),
        jnp.int32,
    )
    ctx = jnp.asarray(RNG.integers(1, mb * bt, size=(b,)), jnp.int32)
    out = ops.paged_attention(q, pool, tbl, ctx, mode="pallas")
    want = ref.paged_attention_ref(q, pool, tbl, ctx)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


# ---------------------------------------------------------------------------
# gather-write / scatter-read roundtrip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,n_slots,bt,hkv,hd", [(3, 8, 16, 2, 32), (1, 4, 8, 1, 16)])
def test_kv_transfer_roundtrip(dtype, L, n_slots, bt, hkv, hd):
    k = _randn((L, n_slots * bt, hkv, hd), dtype)
    v = _randn((L, n_slots * bt, hkv, hd), dtype)
    slots = jnp.asarray(RNG.choice(n_slots, size=3, replace=False), jnp.int32)
    blocks_p = ops.kv_gather_write(k, v, slots, bt, mode="pallas")
    blocks_r = ref.kv_gather_write_ref(k, v, slots, bt)
    assert jnp.array_equal(blocks_p, blocks_r)
    k2, v2 = ops.kv_scatter_read(blocks_p, slots, n_slots, mode="pallas")
    for s in np.asarray(slots):
        assert jnp.array_equal(k2[:, s * bt : (s + 1) * bt], k[:, s * bt : (s + 1) * bt])
        assert jnp.array_equal(v2[:, s * bt : (s + 1) * bt], v[:, s * bt : (s + 1) * bt])


def test_sparse_gather_matches_oracle():
    kv = _randn((64, 2, 32), jnp.float32)
    ids = jnp.asarray(RNG.choice(64, size=17, replace=False), jnp.int32)
    out = ops.sparse_kv_gather(kv, ids, mode="pallas")
    assert jnp.array_equal(out, ref.sparse_kv_gather_ref(kv, ids))


# ---------------------------------------------------------------------------
# hypothesis property tests on kernel invariants
# ---------------------------------------------------------------------------

from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(
    n_sel=st.integers(1, 16),
    n_tokens=st.integers(16, 64),
)
def test_sparse_gather_property(n_sel, n_tokens):
    kv = jnp.arange(n_tokens * 2 * 8, dtype=jnp.float32).reshape(n_tokens, 2, 8)
    rng = np.random.default_rng(n_sel * 977 + n_tokens)
    ids = jnp.asarray(rng.integers(0, n_tokens, size=n_sel), jnp.int32)
    out = ops.sparse_kv_gather(kv, ids, mode="pallas")
    assert out.shape == (n_sel, 2, 8)
    for i, t in enumerate(np.asarray(ids)):
        assert jnp.array_equal(out[i], kv[t])


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_gather_scatter_is_permutation_safe(data):
    """gather_write then scatter_read restores slots for ANY slot permutation."""
    n_slots = 6
    L, bt, hkv, hd = 2, 8, 1, 16
    n_blocks = data.draw(st.integers(1, n_slots))
    slots = data.draw(
        st.permutations(list(range(n_slots))).map(lambda p: p[:n_blocks])
    )
    k = jnp.asarray(
        np.random.default_rng(42).normal(size=(L, n_slots * bt, hkv, hd)),
        jnp.float32,
    )
    slots_arr = jnp.asarray(list(slots), jnp.int32)
    blocks = ops.kv_gather_write(k, k, slots_arr, bt, mode="jnp")
    k2, v2 = ops.kv_scatter_read(blocks, slots_arr, n_slots, mode="jnp")
    for s in slots:
        assert jnp.array_equal(k2[:, s * bt : (s + 1) * bt], k[:, s * bt : (s + 1) * bt])


# ---------------------------------------------------------------------------
# ssd_chunk (Mamba-2 intra-chunk SSD)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,lc,nh,hp,n,tile", [(2, 32, 8, 16, 8, 4), (1, 16, 4, 8, 16, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_matches_oracle(nb, lc, nh, hp, n, tile, dtype):
    x = _randn((nb, lc, nh, hp), dtype)
    a = jnp.asarray(-np.abs(RNG.normal(size=(nb, lc, nh))) * 0.1, jnp.float32)
    b = _randn((nb, lc, nh, n), dtype)
    c = _randn((nb, lc, nh, n), dtype)
    yp, sp = ops.ssd_chunk(x, a, b, c, nh_tile=tile, mode="pallas")
    yr, sr = ops.ssd_chunk(x, a, b, c, mode="jnp")
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), atol=tol, rtol=tol)


def test_ssd_chunk_matches_model_path():
    """Kernel output equals the model's _ssd_chunked intra-chunk term on a
    single chunk (the chunk state must agree exactly with the scan path)."""
    from repro.models.mamba import _ssd_chunked

    rng = np.random.default_rng(3)
    b, s, nh, hp, n = 1, 32, 4, 8, 8
    x = jnp.asarray(rng.normal(size=(b, s, nh, hp)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(b, s, nh))) * 0.1, jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    y_model, state_model = _ssd_chunked(x, a, bm, cm, chunk=s)  # one chunk
    bh = jnp.broadcast_to(bm, (b, s, nh, n))
    ch = jnp.broadcast_to(cm, (b, s, nh, n))
    yk, sk = ops.ssd_chunk(x, a, bh, ch, nh_tile=4, mode="pallas")
    np.testing.assert_allclose(np.asarray(y_model[:, :s].reshape(b, s, nh, hp)),
                               np.asarray(yk), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state_model), np.asarray(sk[0][None]),
                               atol=1e-4, rtol=1e-4)

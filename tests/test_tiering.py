"""Tiered pool subsystem: TieredPool, hotness policy, migration engine."""

import numpy as np
import pytest

from repro.core.index import GlobalIndex
from repro.core.pool import OutOfPoolMemory, PoolLayout
from repro.core.transfer import TransferEngine
from repro.kvcache.hbm_cache import HbmPagedCache
from repro.kvcache.manager import KVCacheManager
from repro.serving.request import Request
from repro.serving.scheduler import Cluster, ClusterConfig
from repro.tiering import HotnessTracker, MigrationEngine, TieredPool, TieringConfig

LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)


def _tiered(fast=64, spill=256, backing="meta", **cfg_kw):
    cfg_kw.setdefault("migrate_interval_s", 0.01)
    cfg_kw.setdefault("migrate_batch_blocks", 16)
    cfg_kw.setdefault("high_watermark", 0.9)
    cfg_kw.setdefault("demote_target", 0.5)
    cfg = TieringConfig(enabled=True, **cfg_kw)
    return TieredPool(LAYOUT, fast, spill, n_shards=32, backing=backing, cfg=cfg)


def _manager(pool):
    idx = GlobalIndex(pool)
    idx.on_evict = pool.policy.ghost_add
    hbm = HbmPagedCache(512, 16)
    mgr = KVCacheManager(pool, idx, hbm, TransferEngine(pool))
    return mgr, idx


def _tokens(doc, n_blocks):
    return [doc * 100000 + i for i in range(n_blocks * 16)]


# ---------------------------------------------------------------------------
# TieredPool: id space, allocation policy, data plane
# ---------------------------------------------------------------------------


def test_tiered_pool_allocates_fast_first_and_splits_id_space():
    p = _tiered(fast=64, spill=256)
    ids = p.allocate(32)
    assert all(b < p.offset for b in ids)  # unpressured -> all fast
    assert p.free_blocks() == 64 + 256 - 32
    p.release(ids)
    assert p.free_blocks() == 320


def test_tiered_pool_overflows_into_spill_and_raises_when_full():
    p = _tiered(fast=64, spill=64)
    ids = p.allocate(100)  # > fast capacity: must span both tiers
    assert sum(b < p.offset for b in ids) == 64
    assert sum(b >= p.offset for b in ids) == 36
    with pytest.raises(OutOfPoolMemory):
        p.allocate(64 + 64 - 100 + 1)


def test_tiered_pool_pressured_writes_go_to_spill_unless_ghost_hot():
    p = _tiered(fast=64, spill=64, high_watermark=0.5)
    held = p.allocate(40)  # fast occupancy 62% > watermark
    p.policy.ghost_add([b"returning"])
    ids = p.allocate(2, keys=[b"returning", b"new"])
    assert ids[0] < p.offset  # ghost-hot key forced fast
    assert ids[1] >= p.offset  # fresh key spilled under pressure
    assert p.tier_stats.ghost_admits == 1
    p.release(held + ids)


def test_tiered_pool_numpy_roundtrip_across_tiers():
    p = _tiered(fast=32, spill=32, backing="numpy")
    ids = p.allocate(40)  # spans both tiers
    payload = np.arange(
        40 * LAYOUT.block_bytes, dtype=np.int64
    ).astype(np.uint8).reshape(40, LAYOUT.block_bytes)
    eps = p.write_blocks(ids, payload)
    got, eps_now = p.read_blocks(ids)
    assert (got == payload).all()
    assert (eps_now == np.asarray(eps)).all()
    assert p.validate_epochs(ids, eps).all()
    # releasing bumps epochs in the right sub-pool (recycle detection)
    p.release(ids)
    assert not p.validate_epochs(ids, eps).any()


def test_tiered_pool_refcount_view_spans_tiers():
    p = _tiered(fast=32, spill=32)
    ids = p.allocate(40)
    fast_id = min(ids)
    spill_id = max(ids)
    assert spill_id >= p.offset
    assert p.refcounts[fast_id] == 1 and p.refcounts[spill_id] == 1
    p.retain([fast_id, spill_id])
    assert p.refcounts[fast_id] == 2 and p.refcounts[spill_id] == 2
    p.release([fast_id, spill_id])
    p.release(ids)


# ---------------------------------------------------------------------------
# Hotness policy
# ---------------------------------------------------------------------------


def test_hotness_decay_orders_candidates():
    h = HotnessTracker(8, half_life_s=1.0)
    h.touch([0], now=0.0)
    h.touch([1], now=0.0)
    h.touch([1], now=0.5)
    h.touch([2], now=10.0)  # one recent touch beats two decayed ones
    cold = h.coldest([0, 1, 2], 3, now=10.0)
    assert cold.tolist() == [0, 1, 2]
    hot = h.hottest([0, 1, 2], 1, now=10.0)
    assert hot.tolist() == [2]


def test_ghost_admission_fires_once_and_is_bounded():
    h = HotnessTracker(4, ghost_capacity=2)
    h.ghost_add([b"a", b"b", b"c"])  # capacity 2: b"a" aged out
    assert not h.admit_hot(b"a")
    assert h.admit_hot(b"c")
    assert not h.admit_hot(b"c")  # consumed
    assert h.ghost_hits == 1


# ---------------------------------------------------------------------------
# Migration engine
# ---------------------------------------------------------------------------


def test_migrator_demotes_cold_blocks_and_keeps_prefix_fetchable():
    pool = _tiered(fast=64, spill=256)
    mgr, idx = _manager(pool)
    mig = MigrationEngine(pool, idx, pool.cfg)
    # fill fast past the watermark with two docs
    mgr.writeback("a", _tokens(1, 30), now=0.0)
    mgr.writeback("b", _tokens(2, 30), now=0.0)
    assert pool.fast_occupancy() > 0.9
    # keep doc 2 hot; doc 1 stays cold
    mgr.plan_fetch(_tokens(2, 30), now=0.1)
    mig.run_until(1.0)
    assert pool.tier_stats.demotions > 0
    assert pool.fast_occupancy() <= 0.9
    # the demoted prefix is still indexed (now in the spill tier) and the
    # full manager fetch path works against its remapped entries
    plan = mgr.plan_fetch(_tokens(1, 30), now=1.1)
    assert plan.n_hit_tokens == 30 * 16
    assert any(b >= pool.offset for _, b, _ in plan.hit_blocks)
    slots = mgr.fetch_into_hbm("r1", plan)
    assert len(slots) == 30
    mgr.finish("r1")


def test_migrator_promotes_rehot_spill_blocks():
    pool = _tiered(fast=64, spill=256, promote_min_heat=2.0)
    mgr, idx = _manager(pool)
    mig = MigrationEngine(pool, idx, pool.cfg)
    mgr.writeback("a", _tokens(1, 30), now=0.0)
    mgr.writeback("b", _tokens(2, 30), now=0.0)
    mgr.plan_fetch(_tokens(2, 30), now=0.1)  # doc 1 is the cold one
    mig.run_until(1.0)
    assert pool.tier_stats.demotions > 0
    # doc 1 gets hot again: repeated fetches push heat over the threshold
    for i in range(3):
        mgr.plan_fetch(_tokens(1, 30), now=1.0 + 0.1 * i)
    mig.run_until(2.0)
    assert pool.tier_stats.promotions > 0
    plan = mgr.plan_fetch(_tokens(1, 30), now=2.1)
    assert plan.n_hit_tokens == 30 * 16
    assert any(b < pool.offset for _, b, _ in plan.hit_blocks)


def test_migrator_evicts_spill_to_ghost_when_spill_full():
    pool = _tiered(fast=32, spill=32, migrate_batch_blocks=32)
    mgr, idx = _manager(pool)
    mig = MigrationEngine(pool, idx, pool.cfg)
    mgr.writeback("a", _tokens(1, 20), now=0.0)  # fast
    mgr.writeback("b", _tokens(2, 20), now=0.0)  # overflows into spill
    mgr.writeback("c", _tokens(3, 20), now=0.0)  # spill nearly full
    mig.run_until(1.0)  # demotion must destroy cold spill blocks first
    assert pool.tier_stats.spill_evictions > 0
    assert pool.policy.ghost_len() > 0  # destroyed keys armed the filter


# ---------------------------------------------------------------------------
# Migrator block conservation (property test, seeded rng — runs without
# hypothesis; the invariants are the point, the seeds are the generator)
# ---------------------------------------------------------------------------


def _assert_blocks_conserved(pool, idx):
    """Every pool block is free XOR owned by exactly one index row; owned
    blocks are committed with refcount 1 (nothing else holds refs in this
    harness), and the reverse map agrees with the rows."""
    shards = idx.shards if hasattr(idx, "shards") else [idx]
    owned = []
    for sh in shards:
        with sh._lock:
            for key, r in sh._rows.items():
                b = int(sh._block_id[r])
                assert sh._block2row[b] == r, "reverse map out of sync"
                owned.append(b)
    assert len(owned) == len(set(owned)), "block owned by two rows"
    assert pool.free_blocks() == pool.n_blocks - len(owned), "block lost/leaked"
    if owned:
        ids = np.asarray(owned, np.intp)
        assert np.asarray(pool.committed[ids], bool).all()
        assert (np.asarray(pool.refcounts[ids]) == 1).all()


def _assert_pending_live(pool):
    """``promote_pending`` must never point at freed/recycled ids after a
    migrator step (the leftover-retry bookkeeping keeps only live,
    unreferenced, committed spill blocks)."""
    for b in pool.promote_pending:
        assert b >= pool.offset, "fast id enqueued for promotion"
        lb = b - pool.offset
        assert pool.spill.committed[lb], "pending id no longer committed"
        assert pool.spill.refcounts[lb] == 1, "pending id freed/re-referenced"


def test_migrator_prunes_stale_pending_on_demote_steps():
    """Regression (ISSUE-4): a demote-only migrator step used to leave
    ``promote_pending`` ids that a foreground eviction had freed between
    steps — the prune now runs every step, not just on promote passes."""
    pool = _tiered(
        fast=32, spill=64, migrate_batch_blocks=4,
        high_watermark=0.8, demote_target=0.5, promote_min_heat=1.0,
    )
    mgr, idx = _manager(pool)
    mig = MigrationEngine(pool, idx, pool.cfg)
    mgr.writeback("spill_doc", _tokens(1, 8), now=0.0)  # fills fast a bit
    mig.run_until(0.0)
    # push one doc's blocks to spill by hand-demoting via pressure
    mgr.writeback("fill", _tokens(2, 22), now=0.01)  # fast > watermark
    mig.run_until(0.05)  # demotes; spill now holds cold blocks
    # make a spill block promotion-pending via hot demand
    spill_ids = [b for b in range(pool.offset, pool.n_blocks)
                 if pool.spill.refcounts[b - pool.offset] == 1
                 and pool.spill.committed[b - pool.offset]]
    assert spill_ids, "expected demoted blocks in spill"
    for t in range(3):
        pool.touch_demand(spill_ids[:2], now=0.06 + 0.01 * t)
    assert pool.promote_pending
    # push fast back above the watermark FIRST (its allocations must not
    # recycle the victim slot after the eviction below), then pin every
    # fast block so the demote step under test migrates nothing
    mgr.writeback("more", _tokens(3, 20), now=0.1)
    assert pool.fast_occupancy() >= pool.cfg.high_watermark
    fast_busy = [
        b for b in range(pool.offset)
        if pool.fast.refcounts[b] == 1 and pool.fast.committed[b]
    ]
    pool.retain(fast_busy)
    # foreground eviction frees a pending block between steps
    victim = next(iter(pool.promote_pending))
    assert idx.evict_blocks([victim]) == [victim]
    mig.run_until(0.2)  # demote-branch steps only: no allocations
    pool.release(fast_busy)
    assert victim not in pool.promote_pending
    _assert_pending_live(pool)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 17, 23])
def test_migrator_churn_conserves_blocks(seed):
    """Random demote/promote/evict churn never loses or duplicates a
    block, and never leaves ``promote_pending`` pointing at freed ids."""
    rng = np.random.default_rng(seed)
    pool = _tiered(
        fast=32, spill=64, migrate_batch_blocks=8,
        high_watermark=0.8, demote_target=0.5, promote_min_heat=2.0,
    )
    mgr, idx = _manager(pool)
    mig = MigrationEngine(pool, idx, pool.cfg)
    now = 0.0
    for step in range(80):
        now += float(rng.uniform(0.0, 0.06))
        op = int(rng.integers(0, 10))
        doc = int(rng.integers(0, 8))
        nb = int(rng.integers(1, 6))
        if op < 4:  # publish (chains share per-doc prefixes: real churn)
            mgr.writeback(f"w{step}", _tokens(doc, nb), now=now)
        elif op < 7:  # demand (heat + promotion signal)
            mgr.plan_fetch(_tokens(doc, nb), now=now)
        elif op < 8:  # foreground pool pressure
            idx.evict_lru(int(rng.integers(1, 8)))
        else:  # targeted eviction of arbitrary ids (unindexed ones skip)
            ids = rng.integers(0, pool.n_blocks, size=4).tolist()
            idx.evict_blocks(ids)
        steps_before = mig.steps
        mig.run_until(now)
        _assert_blocks_conserved(pool, idx)
        if mig.steps > steps_before:
            _assert_pending_live(pool)
    # a drain of everything still balances the books
    idx.evict_lru(pool.n_blocks)
    _assert_blocks_conserved(pool, idx)
    assert pool.free_blocks() == pool.n_blocks


# ---------------------------------------------------------------------------
# 3-level chain: stats guards, _TierView reference semantics, allocate
# conservation (property tests, seeded rng — same convention as above)
# ---------------------------------------------------------------------------


def test_stats_never_divide_by_zero_on_empty_tiers():
    """Regression: ``fast_occupancy``/``spill_occupancy``/``stats_dict``
    raised ZeroDivisionError the moment a tier had 0 blocks (legal config:
    a chain being grown/shrunk, or destroy-on-evict expressed as
    spill=0)."""
    p = _tiered(fast=64, spill=0)
    assert p.fast_occupancy() == 0.0
    d = p.stats_dict()
    assert d["spill_occupancy"] == 0.0 and d["spill_blocks"] == 0
    ids = p.allocate(8)  # pressure check divides by spill capacity too
    assert p.stats_dict()["fast_occupancy"] == 8 / 64
    p.release(ids)
    # all-empty fast is the dual hazard (occupancy of a 0-block tier)
    q = _tiered(fast=0, spill=64)
    assert q.fast_occupancy() == 0.0
    assert q.stats_dict()["fast_occupancy"] == 0.0
    got = q.allocate(4)
    assert all(b >= q.offset for b in got)
    q.release(got)
    # a 0-block tier deep in the chain reports occupancy 0.0 as well
    r = _tiered(fast=32, spill=32, extra_tiers=((0, "ssd"),))
    assert r.tier_occupancy(2) == 0.0
    assert r.stats_dict()["tier_occupancy"][2] == 0.0


def _chain_pool():
    """3-tier chain with live cross-tier state for the view tests."""
    p = _tiered(fast=32, spill=32, extra_tiers=((40, "ssd"),))
    assert p.n_tiers == 3 and p.n_blocks == 32 + 32 + 64  # 40 rounds up
    held = p.allocate(80)  # spans all three tiers
    p.retain(held[::3])  # uneven refcounts
    p.write_blocks(held[::2])  # uneven epochs/committed
    return p, held


@pytest.mark.parametrize("seed", range(6))
def test_tier_view_matches_concatenated_reference(seed):
    """``_TierView.__getitem__`` must be indistinguishable from indexing
    one flat concatenated array: scalars (int and np.integer), 0-d
    arrays, empty + duplicate + unsorted fancy indices across tier
    boundaries, and boolean masks over the global id space."""
    rng = np.random.default_rng(seed)
    p, _ = _chain_pool()
    n = p.n_blocks
    views = [p.refcounts, p.epochs, p.committed]
    refs = [
        np.concatenate([np.asarray(t.refcounts) for t in p.tiers]),
        np.concatenate([np.asarray(t.epochs) for t in p.tiers]),
        np.concatenate([np.asarray(t.committed) for t in p.tiers]),
    ]
    for view, ref in zip(views, refs):
        assert len(view) == n
        for i in (0, 31, 32, 63, 64, n - 1, int(rng.integers(0, n))):
            assert view[i] == ref[i]  # python int scalar
            assert view[np.intp(i)] == ref[np.intp(i)]  # np.integer
            assert view[np.array(i)] == ref[np.array(i)]  # 0-d array
        fancies = [
            np.array([], dtype=np.intp),  # empty fancy index
            rng.integers(0, n, size=int(rng.integers(1, 3 * n))),
            np.array([31, 32, 63, 64, 64, 31]),  # boundaries + dups
            np.flip(rng.permutation(n)),  # every id, unsorted
        ]
        for ids in fancies:
            np.testing.assert_array_equal(view[ids], ref[ids])
        mask = rng.random(n) < rng.random()  # bool mask, varying density
        np.testing.assert_array_equal(view[mask], ref[mask])
        np.testing.assert_array_equal(
            view[np.zeros(n, bool)], ref[np.zeros(n, bool)]
        )


def test_ghost_admission_survives_capacity_clamp_to_spill():
    """A returning (ghost-hot) key whose block the capacity clamp pushed
    down-chain must NOT consume its one-shot admission — it never reached
    the fast tier it was promised."""
    p = _tiered(fast=32, spill=32, high_watermark=0.5)
    held = p.allocate(31)  # fast pressured AND nearly full (1 slot left)
    p.policy.ghost_add([b"k1", b"k2"])
    out = p.allocate(2, keys=[b"k1", b"k2"])
    assert out[0] < p.offset and out[1] >= p.offset  # tail yielded first
    assert p.tier_stats.ghost_admits == 1
    assert not p.policy.ghost_contains(b"k1")  # admitted: consumed
    assert p.policy.ghost_contains(b"k2")  # clamped to spill: preserved
    p.release(held + out)


def test_double_overflow_flips_back_into_fast_head_first():
    """Pressured writes target spill; when spill cannot hold them all the
    overflow flips BACK into fast from the head — the shared prefix stays
    on the fastest medium that has room."""
    p = _tiered(fast=32, spill=32, high_watermark=0.5)
    a = p.allocate(20)  # unpressured: all fast (occupancy now 0.625)
    b = p.allocate(30)  # pressured: all spill (spill free now 2)
    assert all(x >= p.offset for x in b)
    out = p.allocate(10)  # wants spill, only 2 fit: head 8 go fast
    assert [x < p.offset for x in out] == [True] * 8 + [False] * 2
    p.release(a + b + out)


@pytest.mark.parametrize("seed", [0, 1, 2, 5, 11, 29])
def test_allocate_conserves_blocks_and_tier_accounting(seed):
    """Seed-swept allocate churn over a 3-tier chain: every call returns
    exactly n distinct, never-double-allocated ids; the per-tier split
    always matches the ``fast_writes``/``spill_writes``/``tier_writes``
    deltas; ghost one-shot entries are consumed ONLY for blocks that
    really landed fast."""
    rng = np.random.default_rng(seed)
    p = _tiered(
        fast=32, spill=32, extra_tiers=((32, "ssd"),), high_watermark=0.5
    )
    held: list[int] = []
    for step in range(60):
        if held and rng.random() < 0.4:
            k = int(rng.integers(1, len(held) + 1))
            rng.shuffle(held)
            p.release(held[:k])
            del held[:k]
        n = int(rng.integers(1, 16))
        keys = None
        ghosted: list[bytes] = []
        if rng.random() < 0.7:
            keys = [f"{seed}/{step}/{i}".encode() for i in range(n)]
            ghosted = [k for k in keys if rng.random() < 0.3]
            p.policy.ghost_add(ghosted)
        free_before = p.free_blocks()
        pressured = p.fast_occupancy() >= p.watermark(0)
        writes_before = (
            p.tier_stats.fast_writes,
            p.tier_stats.spill_writes,
            tuple(p.tier_writes),
        )
        try:
            out = p.allocate(n, keys=keys)
        except OutOfPoolMemory:
            assert p.free_blocks() < n  # only a genuinely full chain raises
            assert p.free_blocks() == free_before  # nothing leaked
            continue
        # conservation: n distinct fresh ids, books balance exactly
        assert len(out) == n and len(set(out)) == n
        assert not set(out) & set(held)
        assert p.free_blocks() == free_before - n
        # accounting: stats deltas == the realized per-tier split
        _, tix = p._split_tiers(out)
        per_tier = [int((tix == k).sum()) for k in range(p.n_tiers)]
        assert p.tier_stats.fast_writes - writes_before[0] == per_tier[0]
        assert p.tier_stats.spill_writes - writes_before[1] == sum(
            per_tier[1:]
        )
        for k in range(p.n_tiers):
            assert p.tier_writes[k] - writes_before[2][k] == per_tier[k]
        # ghost one-shot: the filter only runs under pressure, and an
        # entry is consumed iff its keyed block actually went fast
        if keys is not None and pressured:
            for key, blk in zip(keys, out):
                if key in ghosted:
                    assert p.policy.ghost_contains(key) == (
                        blk >= p.offset
                    ), (key, blk)
        held += out
    p.release(held)
    assert p.free_blocks() == p.n_blocks


# ---------------------------------------------------------------------------
# Cluster integration
# ---------------------------------------------------------------------------


def _reqs(n, in_len=256, tag="r", n_docs=6):
    reqs = []
    for i in range(n):
        d = i % n_docs
        reqs.append(
            Request(f"{tag}{i}", _tokens(d, in_len // 16), 8, arrival=0.05 * i)
        )
    return reqs


def test_tiered_cluster_completes_and_reports_stats():
    cfg = ClusterConfig(
        n_engines=2, pool_blocks=64, pool_shards=32, hbm_slots_per_engine=256,
        tiering=TieringConfig(
            enabled=True, spill_blocks=512,
            migrate_interval_s=0.01, migrate_batch_blocks=16,
        ),
    )
    c = Cluster(cfg, LAYOUT)
    for r in _reqs(36):
        c.dispatch(r)
    stats = c.run()
    assert stats["n_done"] == 36
    t = stats["tiering"]
    assert t["demotions"] > 0
    assert t["fast_hit_blocks"] + t["spill_hit_blocks"] > 0
    assert t["migrator_steps"] > 0
    # no HBM slot leaks through the tiered fetch path
    for e in c.engines:
        assert e.manager.hbm.free_slots() == e.manager.hbm.n_slots


def _tiered_cluster_cfg(spill_blocks=512, **kw):
    return ClusterConfig(
        n_engines=2, pool_blocks=64, pool_shards=32, hbm_slots_per_engine=256,
        tiering=TieringConfig(
            enabled=True, spill_blocks=spill_blocks,
            migrate_interval_s=0.01, migrate_batch_blocks=16,
        ),
        **kw,
    )


def _run_tiered_cluster(cfg, n=36, n_docs=6):
    with Cluster(cfg, LAYOUT) as c:
        for r in _reqs(n, n_docs=n_docs):
            c.dispatch(r)
        stats = c.run()
        stats["index"] = {
            k: v for k, v in stats["index"].items() if k != "shards"
        }
        return stats, c


def test_tiered_cluster_over_rpc_matches_colocated_migrator():
    """``tiering + index_rpc`` (exp13-style e2e): the migrator's
    owners_of / remap_many / evict_blocks travel the ring, and the WHOLE
    run — TierStats included — is identical to the co-located migrator."""
    colocated, _ = _run_tiered_cluster(_tiered_cluster_cfg())
    over_ring, c = _run_tiered_cluster(
        _tiered_cluster_cfg(index_rpc=True, index_rpc_slots=8)
    )
    assert colocated == over_ring  # TierStats and all summary stats
    assert over_ring["tiering"]["demotions"] > 0
    assert c._rpc_client.stats.requests > 0  # ops really crossed the ring
    # sharded metadata plane underneath the tiered pool also completes
    sharded, c2 = _run_tiered_cluster(
        _tiered_cluster_cfg(index_rpc=True, index_rpc_slots=8, index_shards=2)
    )
    assert sharded["n_done"] == 36
    assert sharded["tiering"]["demotions"] > 0
    assert all(srv.served > 0 for srv in c2._rpc_servers)


def test_tiered_cluster_over_rpc_arms_ghost_list_on_ring_evictions():
    """Spill-eviction keys must still reach the ghost-LRU admission
    filter when the eviction is served over the ring (``on_evict`` fires
    inside the metadata service, which holds the real index shards)."""
    # working set (12 docs x 16 blocks) overflows fast+spill: demotion
    # must destroy cold spill blocks to make room
    cfg = _tiered_cluster_cfg(spill_blocks=64, index_rpc=True,
                              index_rpc_slots=8, index_shards=2)
    stats, c = _run_tiered_cluster(cfg, n=48, n_docs=12)
    t = stats["tiering"]
    assert t["spill_evictions"] > 0
    assert c.pool.policy.ghost_len() > 0 or t["ghost_admits"] > 0


def test_tiering_disabled_is_bit_identical_to_default_config():
    """The subsystem must be zero-cost when off: a config that merely
    *carries* tiering knobs (disabled) reproduces the flat-pool sim
    exactly, stat for stat."""
    results = []
    for tiering in (TieringConfig(), TieringConfig(enabled=False)):
        cfg = ClusterConfig(
            n_engines=2, pool_blocks=256, pool_shards=32,
            hbm_slots_per_engine=256, tiering=tiering,
        )
        c = Cluster(cfg, LAYOUT)
        for r in _reqs(16):
            c.dispatch(r)
        results.append(c.run())
    assert results[0] == results[1]
    assert "tiering" not in results[0]

"""Serving runtime: engine/scheduler behavior, elastic scaling, real e2e."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pool import PoolLayout
from repro.kvcache.hbm_cache import HbmPagedCache, OutOfHbmBlocks
from repro.serving.request import Request, summarize
from repro.serving.scheduler import Cluster, ClusterConfig


LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)


def _reqs(n, in_len=512, out_len=8, tag="r", arrival=0.0, shared_frac=0.5):
    base = list(range(in_len))
    reqs = []
    for i in range(n):
        cut = int(in_len * shared_frac)
        toks = base[:cut] + [10_000 + i] * (in_len - cut)
        reqs.append(Request(f"{tag}{i}", toks, out_len, arrival))
    return reqs


def _cluster(**kw):
    kw.setdefault("n_engines", 4)
    kw.setdefault("pool_blocks", 8192)
    kw.setdefault("hbm_slots_per_engine", 512)
    return Cluster(ClusterConfig(**kw), LAYOUT)


# ---------------------------------------------------------------------------
# hbm paged cache
# ---------------------------------------------------------------------------


def test_hbm_cache_lifecycle():
    h = HbmPagedCache(16, 16)
    slots = h.allocate(4, keys=[b"a", b"b", b"c", b"d"])
    h.register_sequence("s1", slots)
    new = h.extend_sequence("s1", 16, 64)
    assert len(h.table("s1")) == 5 and len(new) == 1
    h.finish_sequence("s1")
    assert h.free_slots() == 16
    with pytest.raises(OutOfHbmBlocks):
        h.allocate(17)


def test_hbm_shared_key_refcount():
    h = HbmPagedCache(8, 16)
    [s] = h.allocate(1, keys=[b"k"])
    assert h.lookup_shared(b"k") == s  # refcount 2 now
    h.release([s])
    assert h.lookup_shared(b"k") == s  # still alive
    h.release([s])
    h.release([s])
    assert h.lookup_shared(b"k") is None
    assert h.free_slots() == 8


# ---------------------------------------------------------------------------
# engine / cluster
# ---------------------------------------------------------------------------


def test_cluster_all_requests_complete():
    c = _cluster()
    for r in _reqs(24):
        c.dispatch(r)
    stats = c.run()
    assert stats["n_done"] == 24
    assert stats["avg_ttft_s"] > 0


def test_cache_hit_run_is_faster_and_hits():
    c = _cluster(transfer_mode="beluga")
    for r in _reqs(16):
        c.dispatch(r)
    s1 = c.run()
    t0 = max(e.clock for e in c.engines)
    for r in _reqs(16, tag="h", arrival=t0):
        c.dispatch(r)
    c.run()
    hits = [r for r in c.requests if r.req_id.startswith("h")]
    s2 = summarize(hits, max(r.t_done for r in hits) - t0)
    assert s2["hit_tokens"] > 0
    assert s2["avg_ttft_s"] < s1["avg_ttft_s"]


def test_beluga_beats_rdma_on_hits():
    res = {}
    for mode in ("beluga", "rdma"):
        c = _cluster(transfer_mode=mode, super_block_tokens=256 if mode == "rdma" else 0)
        for r in _reqs(16, in_len=2048):
            c.dispatch(r)
        c.run()
        t0 = max(e.clock for e in c.engines)
        for r in _reqs(16, in_len=2048, tag="h", arrival=t0):
            c.dispatch(r)
        c.run()
        hits = [r for r in c.requests if r.req_id.startswith("h")]
        res[mode] = summarize(hits, max(r.t_done for r in hits) - t0)
    assert res["beluga"]["avg_ttft_s"] < res["rdma"]["avg_ttft_s"]


def test_straggler_cutover_bounds_fetch():
    """With the cutover on, a pathologically slow fetch path falls back to
    recompute instead of waiting (paper §6.3 / beyond-paper mitigation)."""
    c = _cluster(transfer_mode="rdma", super_block_tokens=16,
                 straggler_cutover=1.0)
    for r in _reqs(8, in_len=4096):
        c.dispatch(r)
    c.run()
    t0 = max(e.clock for e in c.engines)
    for r in _reqs(8, in_len=4096, tag="h", arrival=t0):
        c.dispatch(r)
    c.run()
    cutovers = sum(e.manager.stats.recompute_cutovers for e in c.engines)
    assert cutovers > 0


def test_elastic_remove_engine_requeues_and_completes():
    c = _cluster()
    for r in _reqs(20, out_len=64):
        c.dispatch(r)
    for e in c.engines:
        e.advance(0.5)  # partial progress
    orphans = c.remove_engine(0)  # simulate instance failure
    stats = c.run()
    assert stats["n_done"] == 20  # everything still completes
    assert len(c.engines) == 3


def test_remove_engine_redispatch_is_linear_and_preserves_order(monkeypatch):
    """k orphans -> exactly k routing decisions + k submits, no duplicate
    append to (or O(n) scan of) ``cluster.requests``, original order kept."""
    c = _cluster()
    for r in _reqs(20, out_len=64):
        c.dispatch(r)
    order_before = list(c.requests)
    routed = []
    orig_select = c._select_engine
    monkeypatch.setattr(
        c, "_select_engine", lambda r: routed.append(r) or orig_select(r)
    )
    monkeypatch.setattr(
        c, "dispatch",
        lambda r: pytest.fail("orphan re-dispatch must not re-append"),
    )
    orphans = c.remove_engine(0)
    assert len(routed) == len(orphans) > 0  # O(k) dispatches
    assert c.requests == order_before  # same objects, same order, no dupes
    queued = [r for e in c.engines for r in e.waiting]
    assert sum(1 for r in queued if r in orphans) == len(orphans)
    stats = c.run()
    assert stats["n_done"] == 20


def test_admit_survives_fetch_failure_with_full_recompute(monkeypatch):
    """A fetch_into_hbm failure mid-admission must fall back to recompute
    (empty sequence registered), not KeyError on the table lookup."""
    c = _cluster(n_engines=1)
    for r in _reqs(2, tag="p"):
        c.dispatch(r)
    c.run()  # populate the pool so the next round has prefix hits
    t0 = max(e.clock for e in c.engines)
    eng = c.engines[0]

    def boom(seq_id, plan):
        raise RuntimeError("injected fetch failure")

    monkeypatch.setattr(eng.manager, "fetch_into_hbm", boom)
    reqs = _reqs(2, tag="h", arrival=t0)
    for r in reqs:
        c.dispatch(r)
    c.run()
    assert all(r.state == "done" for r in reqs)
    assert all(r.tokens_out == r.n_output for r in reqs)
    assert eng.manager.hbm.free_slots() == eng.manager.hbm.n_slots


def test_fetch_failure_rolls_back_slots_and_registers_empty_seq():
    """Manager-level hardening: an epoch race inside scatter_read leaks
    neither pool refs nor HBM slots, and the sequence table exists."""
    from repro.core.coherence import CoherenceError

    c = _cluster(n_engines=1)
    for r in _reqs(1, tag="p"):
        c.dispatch(r)
    c.run()
    mgr = c.engines[0].manager
    plan = mgr.plan_fetch(_reqs(1, tag="x")[0].tokens)
    assert plan.hit_blocks
    # rewrite every hit block between plan and fetch: epochs move on
    stale = [b for _, b, _ in plan.hit_blocks]
    mgr.pool.write_blocks(stale)
    free_before = mgr.hbm.free_slots()
    with pytest.raises(CoherenceError):
        mgr.fetch_into_hbm("victim", plan)
    assert mgr.hbm.seq_tables["victim"] == []
    assert mgr.hbm.free_slots() == free_before
    assert (mgr.pool.refcounts >= 0).all()


def test_hbm_has_key_is_public_locality_probe():
    h = HbmPagedCache(8, 16)
    [s] = h.allocate(1, keys=[b"k"])
    assert h.has_key(b"k")
    assert not h.has_key(b"other")
    assert h.refcounts[s] == 1  # no refcount side effect (vs lookup_shared)
    h.release([s])
    assert not h.has_key(b"k")


def test_submit_is_not_a_clock_barrier():
    """Pre-dispatching an open-loop stream with future arrivals must not
    fast-forward the engine clock (the old ``clock = max(clock, now)``
    inflated TTFT for every earlier request); the clock only advances to
    an arrival when the engine actually idles up to it."""
    c = _cluster(n_engines=1)
    eng = c.engines[0]
    early = _reqs(1, in_len=256, out_len=4)[0]
    late = _reqs(1, in_len=256, out_len=4, tag="late", arrival=100.0)[0]
    c.dispatch(early)
    c.dispatch(late)  # pre-dispatched, arrives at t=100
    assert eng.clock == 0.0  # submit left the clock alone
    eng.advance(1.0)
    assert early.t_done is not None and early.ttft < 1.0
    assert eng.clock < 100.0
    assert eng.n_queued == 1 and eng.next_arrival() == 100.0
    c.run()
    assert late.state == "done" and late.t_first_token >= 100.0


def test_drain_survives_arrival_gaps_beyond_advance_horizon():
    """Without the submit clock barrier, a pre-dispatched request arriving
    further out than one drain window (3600 s) must still be served —
    drain's horizon has to reach the next arrival, not misread the idle
    gap as a capacity deadlock."""
    c = _cluster(n_engines=1)
    a = _reqs(1, in_len=256, out_len=4)[0]
    b = _reqs(1, in_len=256, out_len=4, tag="b", arrival=5000.0)[0]
    c.dispatch(a)
    c.dispatch(b)
    stats = c.run()
    assert stats["n_done"] == 2
    assert a.state == "done" and b.state == "done"
    assert b.t_first_token >= 5000.0


def test_elastic_add_engine_no_rebalance_needed():
    c = _cluster(transfer_mode="beluga")
    for r in _reqs(12):
        c.dispatch(r)
    c.run()
    t0 = max(e.clock for e in c.engines)
    eng = c.add_engine()  # scale out; pool is shared -> no KV migration
    reqs = _reqs(4, tag="h", arrival=t0)
    for r in reqs:
        eng.submit(r, t0)
        c.requests.append(r)
    c.run()
    assert all(r.state == "done" for r in reqs)
    assert any(r.hit_tokens > 0 for r in reqs)  # new engine reads old KV


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 20),
    in_len=st.sampled_from([64, 256, 1024]),
    policy=st.sampled_from(["cache_oblivious", "cache_aware", "round_robin"]),
)
def test_cluster_liveness_property(n, in_len, policy):
    """Every dispatched request finishes with sane timestamps, any policy."""
    c = _cluster(policy=policy)
    for r in _reqs(n, in_len=in_len, out_len=4):
        c.dispatch(r)
    stats = c.run()
    assert stats["n_done"] == n
    for r in c.requests:
        assert r.t_done >= r.t_first_token >= r.arrival
        assert r.tokens_out == r.n_output
    # no leaked HBM slots
    for e in c.engines:
        assert e.manager.hbm.free_slots() == e.manager.hbm.n_slots


# ---------------------------------------------------------------------------
# real end-to-end engine (actual tokens, actual pool reuse)
# ---------------------------------------------------------------------------


def test_real_engine_pool_reuse_is_exact():
    from repro.serving.real_runner import RealEngine

    eng = RealEngine.create("olmo-1b", max_len=96, pool_blocks=64)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, eng.cfg.vocab_size, size=48).tolist()
    out1, info1 = eng.generate(prompt, max_new=8)
    assert info1["hit_tokens"] == 0
    out2, info2 = eng.generate(prompt, max_new=8)
    assert info2["hit_tokens"] == 48  # full-prefix pool hit
    assert out1 == out2  # pool roundtrip preserves numerics exactly

"""Capacity-pressure paths: pool OOM -> evict -> retry, the straggler
recompute cutover, and reader epoch-retry under concurrent recycle."""

import numpy as np
import pytest

from repro.core.coherence import CoherenceError, CoherentReader
from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.transfer import TransferEngine
from repro.kvcache.hbm_cache import HbmPagedCache
from repro.kvcache.manager import KVCacheManager

LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)


def _manager(pool_blocks=32, mode="beluga", **kw):
    pool = BelugaPool(LAYOUT, pool_blocks, 4, backing="meta")
    idx = GlobalIndex(pool)
    hbm = HbmPagedCache(256, 16)
    mgr = KVCacheManager(pool, idx, hbm, TransferEngine(pool, mode=mode), **kw)
    return mgr, pool, idx


def _tokens(doc, n_blocks):
    return [doc * 100000 + i for i in range(n_blocks * 16)]


# ---------------------------------------------------------------------------
# pool OOM -> evict_lru -> writeback retry
# ---------------------------------------------------------------------------


def test_writeback_pool_oom_evicts_lru_and_retries():
    mgr, pool, idx = _manager(pool_blocks=32)
    assert mgr.writeback("a", _tokens(1, 32)) == 32  # pool now full
    n = mgr.writeback("b", _tokens(2, 16))  # OOM -> evict -> retry succeeds
    assert n == 16
    assert mgr.stats.pool_evictions > 0
    # doc 2 is fully indexed and fetchable; doc 1 lost its evicted prefix
    assert mgr.plan_fetch(_tokens(2, 16)).n_hit_tokens == 16 * 16
    assert mgr.plan_fetch(_tokens(1, 32)).n_hit_tokens < 32 * 16


def test_writeback_skips_offload_when_pool_is_pinned():
    mgr, pool, idx = _manager(pool_blocks=32)
    mgr.writeback("a", _tokens(1, 32))
    pool.retain(list(range(32)))  # everything referenced: eviction refuses
    assert mgr.writeback("b", _tokens(2, 16)) == 0
    pool.release(list(range(32)))


# ---------------------------------------------------------------------------
# straggler mitigation: fetch-vs-recompute cutover in plan_fetch
# ---------------------------------------------------------------------------


def test_recompute_cutover_triggers_on_slow_fetch():
    # RDMA at native 16-token granularity pays the per-superblock staging
    # cost on every block: fetch latency far exceeds recompute time
    mgr, pool, idx = _manager(mode="rdma", recompute_cutover=1.0)
    mgr.transfer.super_block_tokens = 16
    mgr.writeback("a", _tokens(1, 16))
    plan = mgr.plan_fetch(_tokens(1, 16))
    assert plan.recompute
    assert plan.hit_blocks == [] and plan.n_hit_tokens == 0
    assert plan.n_miss_tokens == 16 * 16
    assert mgr.stats.recompute_cutovers == 1


def test_no_cutover_when_disabled_or_fast():
    mgr, pool, idx = _manager(mode="beluga", recompute_cutover=1000.0)
    mgr.writeback("a", _tokens(1, 16))
    plan = mgr.plan_fetch(_tokens(1, 16))
    assert not plan.recompute and plan.n_hit_tokens == 16 * 16
    mgr2, *_ = _manager(mode="rdma", recompute_cutover=None)
    mgr2.transfer.super_block_tokens = 16
    mgr2.writeback("a", _tokens(1, 16))
    assert not mgr2.plan_fetch(_tokens(1, 16)).recompute


# ---------------------------------------------------------------------------
# CoherentReader epoch-retry under concurrent recycle
# ---------------------------------------------------------------------------


def _flaky_pool(n_torn: int):
    """Pool whose read_block observes a moved epoch n_torn times (a
    concurrent recycle racing the copy), then settles."""
    pool = BelugaPool(LAYOUT, 32, 4, backing="numpy")
    real = pool.read_block
    state = {"left": n_torn}

    def flaky(block_id):
        payload, epoch = real(block_id)
        if state["left"] > 0:
            state["left"] -= 1
            return payload, epoch + 1  # torn read: epoch moved mid-copy
        return payload, epoch

    pool.read_block = flaky
    return pool


def test_coherent_reader_retries_on_concurrent_recycle():
    pool = _flaky_pool(n_torn=1)
    [b] = pool.allocate(1)
    payload = np.arange(LAYOUT.block_bytes, dtype=np.uint8)
    epoch = pool.write_block(b, payload)
    reader = CoherentReader(pool)
    out = reader.read_block(b, epoch)
    assert (out == payload).all()
    assert reader.stats.retries == 1
    assert reader.stats.reads == 1


def test_coherent_reader_gives_up_after_max_retries():
    pool = _flaky_pool(n_torn=10)
    [b] = pool.allocate(1)
    epoch = pool.write_block(b, np.zeros(LAYOUT.block_bytes, np.uint8))
    reader = CoherentReader(pool, max_retries=3)
    with pytest.raises(CoherenceError, match="unstable epoch"):
        reader.read_block(b, epoch)
    assert reader.stats.retries == 3


def test_coherent_reader_rejects_recycled_block_upfront():
    pool = BelugaPool(LAYOUT, 32, 4, backing="numpy")
    [b] = pool.allocate(1)
    epoch = pool.write_block(b, np.zeros(LAYOUT.block_bytes, np.uint8))
    pool.release([b])  # recycle bumps the epoch
    with pytest.raises(CoherenceError, match="no longer valid"):
        CoherentReader(pool).read_block(b, epoch)

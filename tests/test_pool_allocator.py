"""New per-shard-stack allocator: churn invariants + seed equivalence.

The vectorized allocator must keep every observable behavior of the seed
single-list implementation (shard placement balance, epoch bumping,
refcount safety, OutOfPoolMemory exactness) while being O(blocks touched)
per call. Equivalence is checked against the FROZEN seed implementation
(``repro.core.seed_baseline.SeedPool``) by replaying recorded random
traces through both.
"""

import numpy as np
import pytest

from repro.core.coherence import CoherenceError
from repro.core.pool import BelugaPool, OutOfPoolMemory, PoolLayout
from repro.core.seed_baseline import SeedPool
from repro.core.transfer import TransferEngine

LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)


def _pool(n_blocks=64, n_shards=8, **kw):
    return BelugaPool(LAYOUT, n_blocks=n_blocks, n_shards=n_shards, **kw)


# ---------------------------------------------------------------------------
# churn invariants
# ---------------------------------------------------------------------------


def test_balance_under_churn_interleaved():
    """Per-shard occupancy stays balanced through allocate/release churn."""
    rng = np.random.default_rng(0)
    p = _pool(n_blocks=256, n_shards=8)
    live = []
    for step in range(200):
        if live and (rng.random() < 0.4 or p.free_blocks() < 16):
            p.release(live.pop(rng.integers(len(live))))
        else:
            live.append(p.allocate(int(rng.integers(1, 16))))
        occ = p.shard_occupancy()
        # incremental counters must agree with ground truth
        assert sum(occ) == 256 - p.free_blocks()
        # round-robin placement keeps shards within a small band
        assert max(occ) - min(occ) <= 16, (step, occ)
    for lst in live:
        p.release(lst)
    assert p.free_blocks() == 256
    assert p.shard_occupancy() == [0] * 8


def test_fresh_allocation_is_maximally_balanced():
    p = _pool(n_blocks=256, n_shards=8)
    p.allocate(100)
    occ = p.shard_occupancy()
    assert max(occ) - min(occ) <= 1, occ


def test_refcount_epoch_safety_on_release():
    p = _pool(backing="numpy")
    eng = TransferEngine(p)
    [b] = p.allocate(1)
    [e] = eng.gather_write([b], np.zeros((1, LAYOUT.n_fragments, 16, 2, 8), np.float16))
    assert p.validate_epoch(b, e)
    p.retain([b])
    p.release([b])  # refcount 2 -> 1: still live
    assert p.validate_epoch(b, e)
    p.release([b])  # refcount 0: recycled, epoch bumped
    assert not p.validate_epoch(b, e)
    assert p.free_blocks() == 64
    with pytest.raises(CoherenceError):
        eng.scatter_read([b], [e])


def test_double_free_asserts():
    p = _pool()
    a = p.allocate(2)
    p.release(a)
    with pytest.raises(AssertionError):
        p.release(a)


def test_retain_of_free_block_asserts():
    p = _pool()
    [b] = p.allocate(1)
    p.release([b])
    with pytest.raises(AssertionError):
        p.retain([b])


def test_release_with_duplicate_ids_frees_once():
    p = _pool()
    [b] = p.allocate(1)
    p.retain([b])  # refcount 2
    p.release([b, b])  # both decrements in ONE batch
    assert p.free_blocks() == 64
    # block must be back in exactly one free stack
    assert sum(len(s) for s in p._free_by_shard) == 64


def test_out_of_pool_memory_exactness():
    p = _pool(n_blocks=64)
    p.allocate(60)
    with pytest.raises(OutOfPoolMemory):
        p.allocate(5)
    assert p.free_blocks() == 4  # failed call must not leak anything
    got = p.allocate(4)  # exactly the remaining capacity succeeds
    assert len(got) == 4
    with pytest.raises(OutOfPoolMemory):
        p.allocate(1)


def test_batched_epoch_validation_matches_scalar():
    p = _pool(backing="numpy")
    eng = TransferEngine(p)
    blocks = p.allocate(8)
    eps = eng.gather_write(
        blocks, np.zeros((8, LAYOUT.n_fragments, 16, 2, 8), np.float16)
    )
    p.release(blocks[4:])  # recycle half
    batch = p.validate_epochs(blocks, eps)
    scalar = [p.validate_epoch(b, e) for b, e in zip(blocks, eps)]
    assert batch.tolist() == scalar == [True] * 4 + [False] * 4


def test_scatter_read_into_preallocated_out():
    p = _pool(backing="numpy")
    eng = TransferEngine(p)
    kv = np.random.default_rng(3).normal(
        size=(4, LAYOUT.n_fragments, 16, 2, 8)
    ).astype(np.float16)
    blocks = p.allocate(4)
    eps = eng.gather_write(blocks, kv)
    dst = np.empty_like(kv)
    got = eng.scatter_read(blocks, eps, out=dst)
    assert got is dst
    assert np.array_equal(dst, kv)


# ---------------------------------------------------------------------------
# seed equivalence on recorded traces
# ---------------------------------------------------------------------------


def _trace(seed_val: int, n_ops: int = 120, max_alloc: int = 12):
    """Recorded allocate/release trace: deterministic op stream."""
    rng = np.random.default_rng(seed_val)
    ops, live = [], 0
    for _ in range(n_ops):
        if live and rng.random() < 0.45:
            ops.append(("release", int(rng.integers(0, 1 << 30))))
            live -= 1
        else:
            ops.append(("allocate", int(rng.integers(1, max_alloc))))
            live += 1
    return ops


@pytest.mark.parametrize("seed_val", [1, 2, 3])
@pytest.mark.parametrize("interleave", [True, False])
def test_allocator_equivalence_with_seed_impl(seed_val, interleave):
    """The new allocator returns the EXACT block ids (hence shard
    placement), epochs, free counts and OOM points of the seed allocator
    when a recorded trace is replayed through both: the per-shard free
    stacks + fullest-first/oldest-tie order reproduce the seed's per-call
    by-shard rebuild precisely."""
    n_blocks, n_shards = 128, 8
    new = BelugaPool(LAYOUT, n_blocks, n_shards, backing="meta",
                     interleave=interleave)
    old = SeedPool(LAYOUT, n_blocks, n_shards, interleave=interleave)
    live_new, live_old = [], []
    for op, arg in _trace(seed_val):
        if op == "allocate":
            try:
                got_old = old.allocate(arg)
            except OutOfPoolMemory:
                with pytest.raises(OutOfPoolMemory):
                    new.allocate(arg)
                continue
            got_new = new.allocate(arg)
            live_old.append(got_old)
            live_new.append(got_new)
            assert got_new == got_old  # identical ids AND order
        else:
            if not live_old:
                continue
            i = arg % len(live_old)
            old.release(live_old.pop(i))
            new.release(live_new.pop(i))
        assert old.free_blocks() == new.free_blocks()
        assert old.shard_occupancy() == new.shard_occupancy()
    # identical recycle history => identical per-block epochs
    assert [m.epoch for m in old.meta] == new.epochs.tolist()


@pytest.mark.parametrize("n_alloc", [17, 20, 23])
def test_allocator_equivalence_degenerate_fallback(n_alloc):
    """Skewed free state (one fat shard + crumbs) trips the seed's
    round-robin iteration-cap fallback; the new allocator must return the
    same ids through its replicated fallback sweep."""
    def skew(pool):
        pool.allocate(128)
        pool.release([b for b in range(128) if b % 8 == 0]
                     + [1, 10, 19, 28, 37, 46, 55])

    old = SeedPool(LAYOUT, 128, 8)
    new = BelugaPool(LAYOUT, 128, 8, backing="meta")
    skew(old)
    skew(new)
    assert old.allocate(n_alloc) == new.allocate(n_alloc)
    assert old.shard_occupancy() == new.shard_occupancy()

"""Differential property suite: ONE model, every metadata-plane backend.

Random op streams (publish / match / lookup / filter / release-hole /
evict_lru / evict_blocks / remap) are replayed against every way the repo
can run the metadata plane:

  * in-process ``GlobalIndex``            (the reference model)
  * in-process ``ShardedIndex``           (S partitions, one front)
  * thread-ring                           (ShmRing + CxlRpcServer threads)
  * process-ring                          (shared-memory ShmRing + one
                                           metadata service OS process per
                                           shard, repro.core.procserver)

asserting identical observable results op for op.  This is the single
harness that pins every transport x sharding combination to one model:
any divergence — codec, chunking, fan-out merge, eviction-quota policy,
deferred cross-process pool release — fails here with the exact op trace.

Two comparison scopes, because sharding legitimately changes SOME
internals: a stale entry mid-chain is garbage-collected per shard, so
after hole-poking the surviving entry sets may differ between S=1 and
S>1 (documented in ``ShardedIndex``).  Therefore:

  * CROSS-GROUP (all backends, any S): streams without staleness —
    publish/match/lookup/filter — must agree everywhere;
  * WITHIN-GROUP (same S, all transports): the FULL op set, including
    eviction order, freed lists, remap CAS results, final stats and pool
    free-block counts, must be bit-identical.

Hypothesis drives extra randomized coverage where installed (CI); the
seeded replays below always run so the suite is tier-1 everywhere.
"""

from __future__ import annotations

import hashlib
import random

from hypothesis import given, settings, strategies as st

from repro.core import wire
from repro.core.index import GlobalIndex, ShardedIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.procserver import ProcessRpcServer
from repro.core.rpc import CxlRpcClient, CxlRpcServer, ShmRing

LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)
MAX_LEN = 8  # longest chain a stream publishes


def _key(doc: int, i: int) -> bytes:
    """Synthetic 16-byte chain keys, identical for every backend."""
    return hashlib.blake2b(f"{doc}/{i}".encode(), digest_size=16).digest()


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------
class Backend:
    """One (kind, n_shards) metadata plane over its own private pool.

    ``pool_kind`` selects the pool under the plane:

      * ``flat``    — ``BelugaPool`` (the reference);
      * ``tiered0`` — ``TieredPool`` with ZERO spill capacity: tiering
        machinery engaged but with nowhere to spill, which must be
        bit-identical to the flat pool on every transport;
      * ``tiered``  — a small fast tier over a large spill tier, so op
        streams cross the tier boundary and the metadata plane serves
        global ids spanning sub-pools (over the concatenated shared
        segment in process transport).
    """

    def __init__(self, kind: str, n_shards: int, pool_kind: str = "flat"):
        from repro.tiering import TieredPool, TieringConfig

        self.kind = kind
        if pool_kind == "flat":
            self.pool = BelugaPool(
                LAYOUT, n_blocks=4096, n_shards=8, backing="meta"
            )
        elif pool_kind == "tiered0":
            self.pool = TieredPool(
                LAYOUT, 4096, 0, n_shards=8, backing="meta",
                cfg=TieringConfig(enabled=True),
            )
        elif pool_kind == "tiered":
            self.pool = TieredPool(
                LAYOUT, 32, 4064, n_shards=8, backing="meta",
                cfg=TieringConfig(enabled=True, high_watermark=0.5),
            )
        else:
            raise ValueError(pool_kind)
        self._servers: list = []
        if kind == "inproc":
            self.view = (
                GlobalIndex(self.pool)
                if n_shards == 1
                else ShardedIndex(self.pool, n_shards)
            )
        elif kind == "thread":
            sidx = ShardedIndex(self.pool, n_shards)
            clients = []
            for shard in sidx.shards:
                ring = ShmRing(n_slots=8, payload_bytes=1 << 14)
                self._servers.append(
                    CxlRpcServer(
                        ring,
                        wire.make_index_handler(
                            shard, max_reply=ring.payload_bytes
                        ),
                    ).start()
                )
                clients.append(CxlRpcClient(ring))
            self.view = wire.ShardedRpcIndexClient(
                clients, LAYOUT.block_tokens, hasher=sidx.hasher
            )
        elif kind == "process":
            spec = self.pool.share_meta()
            clients = []
            for _ in range(n_shards):
                srv = ProcessRpcServer(
                    spec, n_slots=8, payload_bytes=1 << 14
                ).start()
                self._servers.append(srv)
                clients.append(CxlRpcClient(srv.ring, liveness=srv.alive))
            # deferred pool reclaim: ring-served evictions release HERE
            self.view = wire.ShardedRpcIndexClient(
                clients, LAYOUT.block_tokens, on_freed=self.pool.release
            )
        else:
            raise ValueError(kind)

    def close(self) -> None:
        for srv in self._servers:
            srv.close()
        self.pool.unshare_meta()

    def __enter__(self) -> "Backend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# op streams + replay
# ---------------------------------------------------------------------------
def make_ops(
    rng: random.Random, n_ops: int, docs: int = 4, staleness: bool = True
) -> list[tuple]:
    ops: list[tuple] = []
    for _ in range(n_ops):
        r = rng.random()
        doc = rng.randrange(docs)
        ln = rng.randint(1, MAX_LEN)
        if r < 0.30 or not ops:
            ops.append(("publish", doc, ln))
        elif r < 0.50:
            ops.append(("match", doc, ln))
        elif r < 0.62:
            ops.append(("lookup", doc, ln))
        elif r < 0.72:
            ops.append(("filter", doc))
        elif not staleness:
            ops.append(("match", doc, ln))
        elif r < 0.80:
            ops.append(("release", doc, rng.randrange(MAX_LEN)))
        elif r < 0.88:
            ops.append(("evict_lru", rng.randint(1, 6)))
        elif r < 0.94:
            ops.append(("evict_blocks", doc))
        else:
            ops.append(("remap", doc, rng.randrange(MAX_LEN)))
    return ops


def replay(backend: Backend, ops: list[tuple]) -> list:
    """Run one op stream; every return value becomes an observation.

    Pool-side effects (allocate/write/release) are driven HERE, from the
    pool-owning side, exactly as the manager does — the index backends
    only ever see metadata ops.  A ``gone`` set guards pool ops against
    re-releasing blocks the stream already freed; it is rebuilt from the
    backend's OWN observations, so the guard never masks a divergence.
    """
    pool, view = backend.pool, backend.view
    chains: dict[int, tuple[list[bytes], list[int], list[int]]] = {}
    gone: set[int] = set()
    obs: list = []
    for op in ops:
        kind = op[0]
        if kind == "publish":
            _, doc, ln = op
            keys = [_key(doc, i) for i in range(ln)]
            blocks = pool.allocate(ln)
            eps = pool.write_blocks(blocks)
            view.publish_many(keys, blocks, eps, LAYOUT.block_tokens)
            gone.difference_update(blocks)  # reallocated: live again
            chains[doc] = (keys, blocks, eps)
            obs.append(("publish", doc, tuple(blocks), tuple(eps)))
        elif kind == "match":
            _, doc, ln = op
            keys = [_key(doc, i) for i in range(ln)]
            hits = view.match_prefix_keys(keys)
            obs.append(("match", doc, tuple((b, e) for _, b, e in hits)))
        elif kind == "lookup":
            _, doc, ln = op
            keys = [_key(doc, i) for i in range(ln)]
            got = view.lookup_many(keys)
            obs.append(
                (
                    "lookup",
                    doc,
                    tuple(
                        None
                        if e is None
                        else (e.block_id, e.epoch, e.n_tokens)
                        for e in got
                    ),
                )
            )
        elif kind == "filter":
            _, doc = op
            keys = [_key(doc, i) for i in range(MAX_LEN)]
            obs.append(("filter", doc, tuple(view.filter_unpublished(keys))))
        elif kind == "release":
            _, doc, i = op
            ch = chains.get(doc)
            if ch is not None and i < len(ch[1]) and ch[1][i] not in gone:
                b = ch[1][i]
                pool.release([b])
                gone.add(b)
                obs.append(("release", doc, b))
        elif kind == "evict_lru":
            freed = view.evict_lru(op[1])
            gone.update(freed)
            obs.append(("evict_lru", tuple(freed)))
        elif kind == "evict_blocks":
            _, doc = op
            ch = chains.get(doc)
            if ch is not None:
                freed = view.evict_blocks(ch[1][::2])
                gone.update(freed)
                obs.append(("evict_blocks", doc, tuple(freed)))
        elif kind == "remap":
            _, doc, i = op
            ch = chains.get(doc)
            if ch is None or i >= len(ch[1]) or ch[1][i] in gone:
                continue
            keys, blocks, _ = ch
            found = view.owners_of([blocks[i]])
            obs.append(("owners", doc, tuple(found[1]), tuple(found[2])))
            if not found[1]:
                continue
            [nb] = pool.allocate(1)
            [ne] = pool.write_blocks([nb])
            ok = view.remap_many(
                [keys[i]], [blocks[i]], [found[2][0]], [nb], [ne]
            )
            obs.append(("remap", doc, tuple(ok)))
            if ok[0]:
                old = blocks[i]
                blocks[i] = nb
                pool.release([old])  # migration done: old copy retired
                gone.add(old)
        else:  # pragma: no cover
            raise ValueError(kind)
    obs.append(("free_blocks", pool.free_blocks()))
    return obs


def _within_group(ops: list[tuple], n_shards: int) -> None:
    """All transports at the same sharding: bit-identical, stats included."""
    results = {}
    stats = {}
    for kind in ("inproc", "thread", "process"):
        with Backend(kind, n_shards) as b:
            results[kind] = replay(b, ops)
            stats[kind] = b.view.stats()
    assert results["thread"] == results["inproc"], (n_shards, "thread")
    assert results["process"] == results["inproc"], (n_shards, "process")
    assert stats["thread"] == stats["inproc"], (n_shards, "thread stats")
    assert stats["process"] == stats["inproc"], (n_shards, "process stats")


def _cross_group(ops: list[tuple]) -> None:
    """Stale-free streams must agree across EVERY backend and sharding."""
    combos = [
        ("inproc", 1),
        ("inproc", 3),
        ("thread", 1),
        ("thread", 3),
        ("process", 1),
        ("process", 3),
    ]
    results = {}
    for kind, s in combos:
        with Backend(kind, s) as b:
            results[(kind, s)] = replay(b, ops)
    ref = results[("inproc", 1)]
    for combo, got in results.items():
        assert got == ref, combo


# ---------------------------------------------------------------------------
# seeded replays — always run (tier-1 on bare interpreters too)
# ---------------------------------------------------------------------------
def test_differential_full_ops_all_transports_sharded():
    for seed in (2, 7):
        _within_group(make_ops(random.Random(seed), 24), n_shards=3)


def test_differential_full_ops_all_transports_unsharded():
    for seed in (3, 11):
        _within_group(make_ops(random.Random(seed), 24), n_shards=1)


def test_differential_stale_free_streams_agree_across_sharding():
    for seed in (5, 13):
        _cross_group(make_ops(random.Random(seed), 20, staleness=False))


def test_differential_deterministic_torture_stream():
    """Hand-built stream that is GUARANTEED to hit every tricky path:
    stale hole -> prefix cut + per-shard GC, remap CAS (win and lose),
    targeted evict_blocks, LRU eviction after touches, republish over
    evicted keys — random draws only sometimes reach these."""
    ops = [
        ("publish", 0, 8),
        ("publish", 1, 6),
        ("publish", 2, 8),
        ("release", 0, 3),   # stale hole mid-chain
        ("match", 0, 8),     # cut at 3; stale row GC'd shard-side
        ("filter", 0),
        ("lookup", 0, 8),
        ("remap", 1, 2),     # CAS win: entry re-points, old copy retired
        ("match", 1, 6),
        ("evict_blocks", 1),  # frees every other block of doc 1
        ("lookup", 1, 6),
        ("match", 2, 8),     # touch doc 2 -> doc 0 leftovers are LRU
        ("evict_lru", 6),
        ("publish", 0, 8),   # republish over evicted/stale keys
        ("match", 0, 8),
        ("filter", 1),
        ("evict_lru", 50),   # drain
        ("lookup", 2, 8),
    ]
    for s in (1, 3):
        _within_group(ops, n_shards=s)


def test_differential_eviction_pressure_stream():
    """A stream that leans on eviction: quota policy + deferred release
    must line up transport-for-transport at S=3."""
    rng = random.Random(42)
    ops: list[tuple] = [("publish", d, MAX_LEN) for d in range(4)]
    for _ in range(10):
        ops.append(("evict_lru", rng.randint(2, 9)))
        ops.append(("publish", rng.randrange(4), rng.randint(1, MAX_LEN)))
        ops.append(("match", rng.randrange(4), MAX_LEN))
    _within_group(ops, n_shards=3)


# ---------------------------------------------------------------------------
# tiered pools join the differential groups (gates lifted: the TieredPool
# exports its metadata like a flat pool, so EVERY transport serves it)
# ---------------------------------------------------------------------------
def test_differential_tiering_off_is_bit_identical_to_flat_pool():
    """A chain with zero spill capacity IS the flat pool: observations
    and stats match the flat twin bit for bit on all four backends."""
    ops = make_ops(random.Random(19), 24)
    for kind, s in (
        ("inproc", 1), ("inproc", 3), ("thread", 3), ("process", 3),
    ):
        with Backend(kind, s, pool_kind="flat") as fb:
            ref = (replay(fb, ops), fb.view.stats())
        with Backend(kind, s, pool_kind="tiered0") as tb:
            got = (replay(tb, ops), tb.view.stats())
        assert got == ref, (kind, s)


def test_differential_tiered_chain_agrees_across_transports():
    """Tiered pool, streams crossing the tier boundary: in-process,
    thread-ring and process-ring (metadata children resolving global ids
    against the CONCATENATED shared segment) must be bit-identical."""
    for seed in (5, 13):
        ops = make_ops(random.Random(seed), 24)
        results = {}
        for kind in ("inproc", "thread", "process"):
            with Backend(kind, 3, pool_kind="tiered") as b:
                results[kind] = (replay(b, ops), b.view.stats())
                # the stream really spilled: rows point past the fast tier
                assert any(
                    b.pool.tier_writes[1:]
                ), "stream never crossed the tier boundary"
        assert results["thread"] == results["inproc"], seed
        assert results["process"] == results["inproc"], seed


# ---------------------------------------------------------------------------
# hypothesis-driven coverage (runs wherever hypothesis is installed)
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31), n_ops=st.integers(4, 28))
def test_differential_property_within_group_sharded(seed, n_ops):
    _within_group(make_ops(random.Random(seed), n_ops), n_shards=3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31), n_ops=st.integers(4, 24))
def test_differential_property_cross_group(seed, n_ops):
    _cross_group(make_ops(random.Random(seed), n_ops, staleness=False))

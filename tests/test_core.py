"""Beluga core: pool/index/coherence/transfer/rpc unit + property tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coherence import CoherenceError, CoherentReader, CoherentWriter
from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, OutOfPoolMemory, PoolLayout
from repro.core.rpc import CxlRpcClient, CxlRpcServer, ModeledRdmaRpc, ShmRing
from repro.core.transfer import TransferEngine


LAYOUT = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)


def _pool(n_blocks=64, **kw):
    return BelugaPool(LAYOUT, n_blocks=n_blocks, n_shards=8, **kw)


# ---------------------------------------------------------------------------
# pool allocator
# ---------------------------------------------------------------------------


def test_pool_allocate_release_roundtrip():
    p = _pool()
    a = p.allocate(10)
    assert len(set(a)) == 10
    assert p.free_blocks() == 54
    p.release(a)
    assert p.free_blocks() == 64


def test_pool_interleave_balances_shards():
    p = _pool()
    p.allocate(32)
    occ = p.shard_occupancy()
    assert max(occ) - min(occ) <= 1, occ  # O9: balanced across shards


def test_pool_no_interleave_fills_first_shard():
    p = BelugaPool(LAYOUT, n_blocks=64, n_shards=8, interleave=False)
    p.allocate(8)
    occ = p.shard_occupancy()
    assert occ[0] == 8 and sum(occ[1:]) == 0, occ


def test_pool_oom():
    p = _pool(n_blocks=8)
    p.allocate(8)
    with pytest.raises(OutOfPoolMemory):
        p.allocate(1)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 8), min_size=1, max_size=12))
def test_pool_allocator_invariants(sizes):
    """Property: allocations are disjoint, frees restore capacity exactly."""
    p = _pool(n_blocks=64)
    live: list[list[int]] = []
    total = 0
    for n in sizes:
        if total + n > 64:
            if live:
                blocks = live.pop(0)
                p.release(blocks)
                total -= len(blocks)
            continue
        blocks = p.allocate(n)
        all_live = {b for lst in live for b in lst}
        assert not (set(blocks) & all_live), "allocated a live block"
        live.append(blocks)
        total += n
        assert p.free_blocks() == 64 - total
    for lst in live:
        p.release(lst)
    assert p.free_blocks() == 64


# ---------------------------------------------------------------------------
# index: chain hashing + epoch validation
# ---------------------------------------------------------------------------


def test_index_prefix_match_and_divergence():
    p = _pool(backing="numpy")
    idx = GlobalIndex(p)
    eng = TransferEngine(p)
    tokens_a = list(range(48))
    tokens_b = list(range(32)) + [999] * 16  # diverges in 3rd block
    blocks = p.allocate(3)
    kv = np.zeros((3, LAYOUT.n_fragments, 16, 2, 8), np.float16)
    epochs = eng.gather_write(blocks, kv)
    for k, b, e in zip(idx.keys_for(tokens_a), blocks, epochs):
        idx.publish(k, b, e, 16)
    assert len(idx.match_prefix(tokens_a)) == 3
    assert len(idx.match_prefix(tokens_b)) == 2  # shared 2-block prefix
    assert len(idx.match_prefix([7] + tokens_a)) == 0  # different start


def test_index_rejects_recycled_blocks():
    p = _pool(backing="numpy")
    idx = GlobalIndex(p)
    eng = TransferEngine(p)
    tokens = list(range(16))
    [b] = p.allocate(1)
    [e] = eng.gather_write([b], np.zeros((1, LAYOUT.n_fragments, 16, 2, 8), np.float16))
    idx.publish(idx.keys_for(tokens)[0], b, e, 16)
    assert len(idx.match_prefix(tokens)) == 1
    p.release([b])  # recycle bumps the epoch
    assert len(idx.match_prefix(tokens)) == 0  # stale entry dropped


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=16, max_size=64))
def test_index_chain_hash_property(tokens):
    """match length == longest common *block* prefix with what was published."""
    p = _pool(backing="numpy")
    idx = GlobalIndex(p)
    eng = TransferEngine(p)
    base = [0, 1, 2, 3] * 16  # 64 tokens -> 4 blocks published
    keys = idx.keys_for(base)
    blocks = p.allocate(len(keys))
    epochs = eng.gather_write(
        blocks, np.zeros((len(keys), LAYOUT.n_fragments, 16, 2, 8), np.float16)
    )
    for k, b, e in zip(keys, blocks, epochs):
        idx.publish(k, b, e, 16)
    got = len(idx.match_prefix(tokens))
    # ground truth: count equal leading blocks
    want = 0
    for i in range(min(len(tokens), 64) // 16):
        if tokens[i * 16 : (i + 1) * 16] == base[i * 16 : (i + 1) * 16]:
            want += 1
        else:
            break
    assert got == want


# ---------------------------------------------------------------------------
# coherence protocol
# ---------------------------------------------------------------------------


def test_coherent_write_read_and_stale_detection():
    p = _pool(backing="numpy")
    w = CoherentWriter(p)
    r = CoherentReader(p)
    [b] = p.allocate(1)
    payload = np.arange(LAYOUT.block_bytes, dtype=np.uint8) % 251
    e = w.write_block(b, payload)
    got = r.read_block(b, e)
    assert np.array_equal(got, payload)
    p.release([b])
    with pytest.raises(CoherenceError):
        r.read_block(b, e)


def test_transfer_roundtrip_and_latency_ordering():
    p1, p2 = _pool(backing="numpy"), _pool(backing="numpy")
    be = TransferEngine(p1, mode="beluga")
    rd = TransferEngine(p2, mode="rdma")
    kv = np.random.default_rng(0).normal(size=(4, LAYOUT.n_fragments, 16, 2, 8)).astype(np.float16)
    b1, b2 = p1.allocate(4), p2.allocate(4)
    e1 = be.gather_write(b1, kv)
    rd.gather_write(b2, kv)
    # the fused path must model faster AND issue fewer requests (§6.1):
    # 4 blocks x 8 fragments -> 1 fused launch vs ceil(32/30)=2 RDMA reqs
    assert be.stats.modeled_write_s < rd.stats.modeled_write_s
    assert be.stats.requests_issued == 1 < rd.stats.requests_issued
    assert np.array_equal(be.scatter_read(b1, e1), kv)


# ---------------------------------------------------------------------------
# rpc ring
# ---------------------------------------------------------------------------


def test_rpc_ring_roundtrip_and_concurrency():
    ring = ShmRing(n_slots=16, payload_bytes=64)
    # handler: increment every byte (verifies request->response data flow)
    server = CxlRpcServer(
        ring, handler=lambda b: bytes((x + 1) % 256 for x in b)
    ).start()
    try:
        client = CxlRpcClient(ring)
        out = client.call(b"\x10" * 16)
        assert out[:16] == b"\x11" * 16
        import threading

        results = []

        def worker(i):
            payload = bytes([i]) * 16
            results.append((payload, client.call(payload)))

        ts = [threading.Thread(target=worker, args=(i,)) for i in range(1, 9)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        for payload, resp in results:
            assert resp[:16] == bytes((x + 1) % 256 for x in payload)
    finally:
        server.stop()


def test_modeled_rpc_latency_gap():
    rc = ModeledRdmaRpc(handler=lambda b: b)
    rc.call(b"x")
    from repro.core.fabric import DEFAULT

    assert DEFAULT.cxl_rpc_rtt * 3.5 < rc.rtt  # ~4x gap (Fig. 15)

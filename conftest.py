"""Root conftest: src/ on sys.path + optional-dependency shim for hypothesis.

The test modules import ``hypothesis`` at module scope.  On a bare
interpreter (no ``pip install -r requirements.txt``) that made COLLECTION
fail for four test files.  When hypothesis is missing we install a stub
into ``sys.modules`` whose ``@given`` marks the test as skipped — example
tests still run, property tests skip cleanly.
"""

from __future__ import annotations

import os
import sys
import types

# make `import repro` work without PYTHONPATH=src
_SRC = os.path.join(os.path.dirname(__file__), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:  # build the skip-stub
    import pytest

    class _Strategy:
        """Placeholder strategy: composable, never executed."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)"
            )(fn)

        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy()
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

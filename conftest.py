"""Root conftest: src/ on sys.path + optional-dependency shim for hypothesis.

The test modules import ``hypothesis`` at module scope.  On a bare
interpreter (no ``pip install -r requirements.txt``) that made COLLECTION
fail for four test files.  When hypothesis is missing we install a stub
into ``sys.modules`` whose ``@given`` marks the test as skipped — example
tests still run, property tests skip cleanly.

With ``BELUGA_SANITIZE=1`` (the nightly sanitizer job) a session-scoped
guard additionally fails the run if the lock-order recorder in
``repro.core.locks`` observed any acquisition-order inversion, and dumps
the recorded graph for the post-run ``--check-lock-log`` gate.
"""

from __future__ import annotations

import os
import sys
import types

import pytest

_ROOT = os.path.dirname(__file__)
# make `import repro` work without PYTHONPATH=src, and `import
# tools.beluga_lint` work regardless of invocation directory
_SRC = os.path.join(_ROOT, "src")
for _p in (_SRC, _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)


@pytest.fixture(scope="session", autouse=True)
def _beluga_sanitize_guard():
    """Under BELUGA_SANITIZE=1, a recorded lock-order inversion anywhere
    in the session is a hard failure (the runtime half of beluga-lint's
    lock-discipline pass)."""
    yield
    if os.environ.get("BELUGA_SANITIZE", "") in ("", "0"):
        return
    from repro.core import locks

    log_dir = os.environ.get("BELUGA_SANITIZE_LOG", "")
    if log_dir:
        os.makedirs(log_dir, exist_ok=True)
        locks.dump(os.path.join(log_dir, f"lock_order.{os.getpid()}.json"))
    vs = locks.violations()
    assert not vs, f"lock-order inversions recorded this session: {vs}"

try:
    import hypothesis  # noqa: F401
except ImportError:  # build the skip-stub
    import pytest

    class _Strategy:
        """Placeholder strategy: composable, never executed."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)"
            )(fn)

        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy()
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

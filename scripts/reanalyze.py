"""Re-run the HLO analyzer over saved .hlo.gz artifacts (no recompiles).

Usage: PYTHONPATH=src python scripts/reanalyze.py [results/dryrun]
"""

import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402


def main(out_dir: str = "results/dryrun") -> None:
    for jf in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(jf) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        hf = os.path.join(out_dir, "hlo", rec["cell"] + ".hlo.gz")
        if not os.path.exists(hf):
            print("missing hlo for", rec["cell"])
            continue
        with gzip.open(hf, "rt") as f:
            hlo = f.read()
        rec["hlo_analysis"] = analyze_hlo(hlo)
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1)
        ha = rec["hlo_analysis"]
        print(
            f"{rec['cell']}: flops={ha['flops']:.3e} bytes={ha['bytes_accessed']:.3e} "
            f"coll={ha['collective_bytes']:.3e}"
        )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")

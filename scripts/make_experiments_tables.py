"""Emit the EXPERIMENTS.md tables from dry-run artifacts.

Usage: PYTHONPATH=src python scripts/make_experiments_tables.py > results/tables.md
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.mesh import HBM_PER_CHIP  # noqa: E402
from repro.launch.roofline import load_records, roofline_terms  # noqa: E402


def dryrun_table() -> str:
    rows = []
    for r in load_records("results/dryrun", tag="baseline"):
        if r.get("status") == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped | — | — | — |"
            )
            continue
        if r.get("status") != "ok":
            continue
        ma = r.get("memory_analysis", {})
        live = ma.get("live_bytes_per_device")
        ha = r["hlo_analysis"]
        coll = ha.get("collective_counts", {})
        coll_s = " ".join(f"{k.replace('all-','a')}:{int(v)}" for k, v in sorted(coll.items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"({r['t_compile_s']}s) | {live/2**30:.1f} | "
            f"{ha['flops']:.2e} | {coll_s} |"
        )
    hdr = (
        "| arch | shape | mesh | compile | live GiB/chip | HLO flops/chip | collectives (count) |\n"
        "|---|---|---|---|---|---|---|\n"
    )
    return hdr + "\n".join(sorted(rows)) + "\n"


def roofline_table(tag="baseline", mesh="pod16x16") -> str:
    rows = [
        t
        for r in load_records("results/dryrun", tag=tag)
        if (t := roofline_terms(r)) and t["mesh"] == mesh
    ]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| cell | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac | fits 16GiB | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    from repro.launch.roofline import HINTS

    for r in rows:
        out.append(
            f"| {r['arch']}.{r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['model_flops_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} | {HINTS[r['dominant']][:60]}… |"
        )
    return "\n".join(out) + "\n"


def variants_table() -> str:
    out = [
        "| cell | variant | flops/chip | bytes/chip | coll bytes/chip |",
        "|---|---|---|---|---|",
    ]
    for f in sorted(glob.glob("results/dryrun/*.json")):
        r = json.load(open(f))
        if r.get("status") != "ok" or r.get("tag", "baseline") == "baseline":
            continue
        base_f = f"results/dryrun/{r['arch']}.{r['shape']}.{r['mesh']}.json"
        if not os.path.exists(base_f):
            continue
        b = json.load(open(base_f))["hlo_analysis"]
        ha = r["hlo_analysis"]
        out.append(
            f"| {r['arch']}.{r['shape']} | {r['tag']} | "
            f"{ha['flops']:.2e} ({b['flops']/max(ha['flops'],1):.2f}x) | "
            f"{ha['bytes_fused']:.2e} ({b['bytes_fused']/max(ha['bytes_fused'],1):.2f}x) | "
            f"{ha['collective_bytes']:.2e} ({b['collective_bytes']/max(ha['collective_bytes'],1):.2f}x) |"
        )
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n### Roofline (single-pod 16x16)\n")
        print(roofline_table())
        print("\n### Roofline (multi-pod 2x16x16)\n")
        print(roofline_table(mesh="pod2x16x16"))
    if which in ("all", "variants"):
        print("\n### Variant cells (vs baseline, ratio = baseline/variant)\n")
        print(variants_table())

"""Exp #12 (beyond-paper): control-plane + data-plane micro-benchmarks.

Times the four hot paths every request crosses — pool allocate/release,
index match_prefix, numpy scatter_read, and the closed-loop engine event
rate — against the FROZEN seed implementations
(``repro.core.seed_baseline``), and emits ``BENCH_control_plane.json`` so
the perf trajectory is tracked from this PR on.

    PYTHONPATH=src python -m benchmarks.exp12_control_plane [--fast]

Acceptance floors (PR 1): >=10x on allocate+release at 65536 blocks /
32 shards, >=5x on a 64-block scatter_read with numpy backing.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import seed_baseline as seed
from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.transfer import TransferEngine

# full runs write the tracked trajectory file; --fast (CI-sized inputs,
# not comparable numbers) writes alongside so it never clobbers it
OUT_PATH = "BENCH_control_plane.json"
OUT_PATH_FAST = "BENCH_control_plane.fast.json"

# measured on the container CPU before/while landing PR 1 (same workload:
# full 3-mode exp05, n=256, in_len=15000) — kept so later PRs can see the
# whole trajectory without checking out the seed. The seed number is the
# QUIETER-machine measurement (a same-conditions worktree re-run gave
# 68.7 s), so the recorded speedup is the conservative one.
EXP05_SEED_WALL_S = 61.7
EXP05_PR1_WALL_S = 11.9
# PR-1 match_prefix on this container (15k tokens / 937 keys, dict-walk
# OrderedDict index): the floor the PR-3 flat-array index is judged
# against (acceptance: >= 4x, i.e. <= ~400 us)
MATCH_PREFIX_PR1_US = 1600.0


def _time(fn, iters: int) -> float:
    """us per call (best of 3 runs)."""
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e6


# ---------------------------------------------------------------------------
def bench_alloc_release(n_blocks: int = 65536, n_shards: int = 32, group: int = 16):
    lay = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)

    def cycle(pool):
        def run():
            batches = [pool.allocate(group) for _ in range(32)]
            for b in batches:
                pool.release(b)

        return run

    seed_pool = seed.SeedPool(lay, n_blocks, n_shards)
    new_pool = BelugaPool(lay, n_blocks, n_shards, backing="meta")
    # one op = one allocate(group) + one release(group)
    seed_us = _time(cycle(seed_pool), 2) / 32
    new_us = _time(cycle(new_pool), 8) / 32
    return {
        "pool_blocks": n_blocks,
        "n_shards": n_shards,
        "group": group,
        "seed_us_per_op": seed_us,
        "new_us_per_op": new_us,
        "speedup": seed_us / new_us,
    }


# ---------------------------------------------------------------------------
def bench_match_prefix(n_tokens: int = 15000, bt: int = 16):
    lay = PoolLayout(block_tokens=bt, n_layers_kv=4, n_kv_heads=2, head_dim=8)
    n_keys = n_tokens // bt
    pool = BelugaPool(lay, 65536, 32, backing="meta")
    idx = GlobalIndex(pool)
    tokens = list(range(n_tokens))
    keys = idx.keys_for(tokens)
    blocks = pool.allocate(n_keys)
    epochs = pool.write_blocks(blocks)
    idx.publish_many(keys, blocks, epochs, bt)

    def run_seed():
        # the seed path: re-derive the chain with per-int str() hashing,
        # then one index lookup + one pool lock round-trip PER key
        skeys = seed.seed_keys_for(tokens, bt)
        out = []
        for k in skeys:
            e = idx.lookup(k)
            if e is None or not pool.validate_epoch(e.block_id, e.epoch):
                break
            out.append((k, e.block_id, e.epoch))
        return out

    def run_new():
        return idx.match_prefix(tokens)

    assert len(run_seed()) == 0  # seed str-hash keys are a different chain
    assert len(run_new()) == n_keys
    seed_us = _time(run_seed, 4)
    new_us = _time(run_new, 16)
    out = {
        "n_tokens": n_tokens,
        "n_keys": n_keys,
        "seed_us_per_match": seed_us,
        "new_us_per_match": new_us,
        "speedup": seed_us / new_us,
    }
    if n_tokens >= 15000:
        # trajectory vs the PR-1 OrderedDict walk — only meaningful at
        # the reference workload size (--fast chains are smaller, and a
        # vs-PR-1 number computed from them would read as comparable)
        out["pr1_us_reference"] = MATCH_PREFIX_PR1_US
        out["speedup_vs_pr1"] = MATCH_PREFIX_PR1_US / new_us
    return out


# ---------------------------------------------------------------------------
def bench_scatter_read(n_read: int = 64, full_layout: bool = True):
    if full_layout:  # Qwen3-32B: 128 fragments, 4 MiB blocks
        lay = PoolLayout(block_tokens=16, n_layers_kv=64, n_kv_heads=8, head_dim=128)
    else:
        lay = PoolLayout(block_tokens=16, n_layers_kv=8, n_kv_heads=2, head_dim=64)
    n_blocks = max(128, 2 * n_read)

    seed_pool = seed.SeedPool(lay, n_blocks, 32, backing="numpy")
    new_pool = BelugaPool(lay, n_blocks, 32, backing="numpy")
    xfer = TransferEngine(new_pool)
    sblocks = seed_pool.allocate(n_read)
    seps = [seed_pool.write_block(b, np.zeros(lay.block_bytes, np.uint8)) for b in sblocks]
    nblocks = new_pool.allocate(n_read)
    neps = new_pool.write_blocks(
        nblocks, np.zeros((n_read, lay.block_bytes), np.uint8)
    )

    seed_us = _time(lambda: seed.seed_scatter_read(seed_pool, sblocks, seps), 3)
    new_alloc_us = _time(lambda: xfer.scatter_read(nblocks, neps), 3)
    # steady-state serving pattern: read into the engine's persistent KV
    # destination (fresh giant allocations — the seed's only option — cost
    # more in page faults than the copy itself)
    dst = np.empty(
        (n_read, lay.n_fragments, lay.block_tokens, lay.n_kv_heads, lay.head_dim),
        np.float16,
    )
    new_us = _time(lambda: xfer.scatter_read(nblocks, neps, out=dst), 3)
    return {
        "n_blocks_read": n_read,
        "block_bytes": lay.block_bytes,
        "seed_us_per_read": seed_us,
        "new_alloc_us_per_read": new_alloc_us,
        "new_us_per_read": new_us,
        "speedup": seed_us / new_us,
    }


# ---------------------------------------------------------------------------
def bench_engine_loop(n: int = 256, n_engines: int = 16, in_len: int = 4096):
    from benchmarks.common import qwen32b_layout, run_populate_then_hit
    from repro.serving.scheduler import ClusterConfig

    cfg = ClusterConfig(n_engines=n_engines, transfer_mode="beluga",
                        pool_blocks=131072)
    t0 = time.perf_counter()
    _s1, _s2, c = run_populate_then_hit(cfg, qwen32b_layout(), n=n, in_len=in_len)
    wall = time.perf_counter() - t0
    events = sum(e.stats.prefills + e.stats.decode_steps for e in c.engines)
    return {
        "n_clients": n,
        "n_engines": n_engines,
        "in_len": in_len,
        "events": events,
        "wall_s": wall,
        "events_per_s": events / wall,
    }


# ---------------------------------------------------------------------------
def run(fast: bool = False) -> list[tuple]:
    results: dict = {"fast": fast}
    results["alloc_release"] = bench_alloc_release()
    results["match_prefix"] = bench_match_prefix(
        n_tokens=4096 if fast else 15000
    )
    results["scatter_read"] = bench_scatter_read(full_layout=not fast)
    results["engine_loop"] = bench_engine_loop(
        n=64 if fast else 256, in_len=2048 if fast else 4096
    )
    results["exp05_reference"] = {
        "seed_wall_s": EXP05_SEED_WALL_S,
        "pr1_wall_s": EXP05_PR1_WALL_S,
        "note": "full 3-mode exp05 (n=256, in_len=15000) wall-clock; "
                "re-measure with `python -m benchmarks.exp05_e2e`",
    }
    out_path = OUT_PATH_FAST if fast else OUT_PATH
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)

    rows = []
    for name in ("alloc_release", "match_prefix", "scatter_read"):
        r = results[name]
        us = [v for k, v in r.items() if k.startswith("new_us")][0]
        derived = (
            f"seed_us={[v for k, v in r.items() if k.startswith('seed_us')][0]:.1f};"
            f"speedup={r['speedup']:.1f}x"
        )
        if "speedup_vs_pr1" in r and not fast:
            derived += f";pr1_us={r['pr1_us_reference']:.0f};vs_pr1={r['speedup_vs_pr1']:.1f}x"
        rows.append((f"exp12.{name}", f"{us:.1f}", derived))
    el = results["engine_loop"]
    rows.append(
        ("exp12.engine_loop", f"{1e6 / el['events_per_s']:.1f}",
         f"events_per_s={el['events_per_s']:.0f};wall_s={el['wall_s']:.2f};"
         f"clients={el['n_clients']}")
    )
    rows.append(
        ("exp12.exp05_wall", f"{EXP05_PR1_WALL_S * 1e6:.0f}",
         f"seed_s={EXP05_SEED_WALL_S};pr1_s={EXP05_PR1_WALL_S};"
         f"speedup={EXP05_SEED_WALL_S / EXP05_PR1_WALL_S:.1f}x")
    )
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI-sized inputs")
    args = ap.parse_args()
    emit(run(fast=args.fast))
    print(f"# wrote {OUT_PATH_FAST if args.fast else OUT_PATH}")

"""Exp #3 (Fig. 7): concurrent skewed access — interleaving on/off.

16 synchronized workers issue 16 KB ops at zipf(0.99)-selected addresses
into the device-queue model; reproduces the paper's finding that WITHOUT
interleaving the first device bottlenecks (lower bandwidth, higher p99).
"""

import numpy as np

from repro.core.fabric import DEFAULT, DeviceQueues


def _zipf_addrs(n, n_blocks, a=0.99, seed=0):
    rng = np.random.default_rng(seed)
    # zipf over block ids (paper: 0.99 skew)
    ranks = np.arange(1, n_blocks + 1, dtype=np.float64)
    p = ranks ** (-a)
    p /= p.sum()
    return rng.choice(n_blocks, size=n, p=p)


def run() -> list[tuple]:
    rows = []
    size = 16 * 1024
    n_ops = 4000
    n_threads = 16
    blocks = _zipf_addrs(n_ops, 4096)
    n_blocks = 4096
    for interleave in (True, False):
        q = DeviceQueues(
            n_devices=32, total_bytes=n_blocks * DEFAULT.interleave_bytes
        )
        lat = []
        done_max = 0.0
        for i, b in enumerate(blocks):
            now = (i // n_threads) * 2e-6  # batched thread issue
            addr = int(b) * DEFAULT.interleave_bytes  # block-sized regions
            done = q.submit(now, addr, size, interleave)
            lat.append(done - now)
            done_max = max(done_max, done)
        lat_us = np.array(lat) * 1e6
        bw = n_ops * size / done_max / 2**30
        tag = "interleave" if interleave else "no_interleave"
        rows.append(
            (f"exp03.{tag}", f"{np.median(lat_us):.2f}",
             f"p99={np.percentile(lat_us, 99):.2f}us;agg_bw={bw:.1f}GiB/s")
        )
    rows.append(
        ("exp03.paper_note", "0",
         "paper: no-interleave bottlenecks on first device (O9)")
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

"""Exp #9 (Fig. 14): dense KVCache scatter-gather transfers per model layout.

One KV block (16 tokens): Qwen3-32B = 128 fragments, Llama-3.1-8B = 64,
Qwen3-32B-FP8 = 128 half-size fragments. Beluga (fused kernel, direct) vs
MoonCake RDMA (bounce buffer + sglist splitting). Paper: -36.2% write /
-38.7% read latency.

Also times the REAL kernels (interpret mode) on reduced shapes to validate
the one-launch property (requests_issued == 1 per batch).
"""

import dataclasses


from benchmarks.common import emit
from repro.configs.registry import get_config
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.transfer import TransferEngine


def run() -> list[tuple]:
    rows = []
    for name, arch, dtype_bytes in [
        ("qwen3-32b", "qwen3-32b", 2),
        ("llama3.1-8b", "llama3.1-8b", 2),
        ("qwen3-32b-fp8", "qwen3-32b", 1),
    ]:
        cfg = get_config(arch)
        layout = dataclasses.replace(
            PoolLayout.for_model(cfg), dtype_bytes=dtype_bytes
        )
        res = {}
        for mode in ("beluga", "rdma"):
            pool = BelugaPool(layout, n_blocks=64, n_shards=8, backing="meta")
            eng = TransferEngine(pool, mode=mode)
            ids = pool.allocate(1)
            eng.gather_write(ids, None)
            eng.scatter_read(ids)
            res[mode] = (
                eng.stats.modeled_write_s * 1e6,
                eng.stats.modeled_read_s * 1e6,
                eng.stats.requests_issued,
            )
        w_cut = 1 - res["beluga"][0] / res["rdma"][0]
        r_cut = 1 - res["beluga"][1] / res["rdma"][1]
        rows.append(
            (f"exp09.{name}.write", f"{res['beluga'][0]:.1f}",
             f"rdma={res['rdma'][0]:.1f}us;cut={100*w_cut:.1f}%"
             f"(paper -36.2%);frags={layout.n_fragments}")
        )
        rows.append(
            (f"exp09.{name}.read", f"{res['beluga'][1]:.1f}",
             f"rdma={res['rdma'][1]:.1f}us;cut={100*r_cut:.1f}%(paper -38.7%)")
        )
    # real kernel single-launch check (reduced shapes, interpret mode)
    import jax.numpy as jnp

    from repro.kernels import ops

    L, n_slots, bt, hkv, hd = 4, 8, 16, 2, 32
    k = jnp.zeros((L, n_slots * bt, hkv, hd), jnp.float32)
    blocks = ops.kv_gather_write(k, k, jnp.arange(4, dtype=jnp.int32), bt, mode="pallas")
    rows.append(
        ("exp09.kernel_single_launch", "1",
         f"kv_gather_write packs {2*L*4} fragments in one pallas_call; "
         f"out shape {tuple(blocks.shape)}")
    )
    return rows


if __name__ == "__main__":
    emit(run())

"""Exp #11 (Fig. 15): CXL-RPC metadata plane — REAL index ops over the ring.

The PR-1/PR-2 version of this harness measured the shared-memory ring
against a toy echo handler; this one serves the actual ``GlobalIndex``
through the ``repro.core.wire`` binary codec, so the numbers are for the
traffic every request really generates:

  * ``match_prefix`` RTT at QD=1 for a paper-scale chain (15k tokens /
    937 keys) in ONE framed message;
  * batched vs per-key ops/s: the same chain shipped as one message (and
    as one OP_BATCH of single-key ops) against 937 individual RPCs — the
    client-side batching path must win by well over the 5x floor;
  * ``publish_many`` batched vs per-key;
  * multi-threaded client throughput over one ring;
  * the paper-calibrated CXL vs RDMA RTT constants alongside (Fig. 15);
  * the SHARD SWEEP: the same multi-client batched-match load against a
    metadata plane sharded S in {1,2,4} ways (S rings, S service threads,
    ``ShardedRpcIndexClient`` posting to every ring before collecting).
    Two numbers per S: wall keys/s (GIL-capped on this host — all S
    service threads share one interpreter, which a real deployment does
    not) and CAPACITY keys/s = chain keys / bottleneck-shard service
    demand, each shard's sub-chain handler timed single-threaded and
    contention-free — the throughput the same shard layout sustains when
    each metadata service thread owns a core (the paper's §6 shape).

Client-side ``RpcStats`` (requests / errors / timeouts, with failed
round-trips' wait time included in the average) are surfaced per section.

Writes ``BENCH_rpc.json`` (``BENCH_rpc.fast.json`` with --fast).

    PYTHONPATH=src python -m benchmarks.exp11_rpc [--fast]
"""

from __future__ import annotations

import json
import threading
import time

from benchmarks.common import emit
from repro.core import wire
from repro.core.fabric import DEFAULT
from repro.core.index import GlobalIndex, ShardedIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.rpc import CxlRpcClient, CxlRpcServer, ShmRing

OUT_PATH = "BENCH_rpc.json"
OUT_PATH_FAST = "BENCH_rpc.fast.json"


def _best(fn, iters: int, repeat: int = 3) -> float:
    """Seconds per call (best of ``repeat`` runs)."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def shard_sweep(n_tokens: int, fast: bool) -> list[dict]:
    """Multi-client batched-match throughput vs metadata shard count.

    Two throughput numbers per shard count:

      * ``wall_keys_per_s`` — real threaded clients against real rings.
        On this host every service thread shares ONE interpreter (GIL),
        so wall aggregate is capped near the 1-thread rate regardless of
        S — a ceiling the paper's deployment (one core per metadata
        service thread) does not have;
      * ``capacity_keys_per_s`` — chain keys / BOTTLENECK-shard service
        time, each shard's sub-chain handler timed single-threaded after
        the load run (contention-free ``perf_counter``; per-thread CPU
        clocks are jiffy-quantized on this kernel, so timing inside the
        threaded run would be noise). This is the plane's sustainable
        rate once each service thread owns a core: the number the
        >=1.5x S=4 scaling floor is about.
    """
    from repro.core.index import partition_keys

    lay = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)
    n_threads, per = (4, 10) if fast else (8, 30)
    svc_iters = 20 if fast else 50
    cells = []
    for n_shards in (1, 2, 4):
        pool = BelugaPool(lay, 65536, 32, backing="meta")
        sidx = ShardedIndex(pool, n_shards)
        rings = [ShmRing(n_slots=64, payload_bytes=1 << 16) for _ in range(n_shards)]
        servers = [
            CxlRpcServer(
                ring, wire.make_index_handler(shard, max_reply=ring.payload_bytes)
            ).start()
            for ring, shard in zip(rings, sidx.shards)
        ]
        clients = [CxlRpcClient(ring) for ring in rings]
        try:
            proxy = wire.ShardedRpcIndexClient(
                clients, lay.block_tokens, hasher=sidx.hasher
            )
            keys = proxy.keys_for(list(range(n_tokens)))
            blocks = pool.allocate(len(keys))
            sidx.publish_many(keys, blocks, pool.write_blocks(blocks), 16)
            for _ in range(5):  # warm (LRU fast path, caches)
                proxy.match_prefix_keys(keys)

            def worker():
                p = wire.ShardedRpcIndexClient(
                    clients, lay.block_tokens, hasher=sidx.hasher
                )
                for _ in range(per):
                    p.match_prefix_keys(keys)

            ts = [threading.Thread(target=worker) for _ in range(n_threads)]
            t0 = time.perf_counter()
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            dt = time.perf_counter() - t0
        finally:
            for srv in servers:
                srv.stop()  # spin threads would skew the service timing
        # per-shard service demand, single-threaded (see docstring)
        key_lists, _ = partition_keys(keys, n_shards)
        service_s = []
        for shard, kl in zip(sidx.shards, key_lists):
            msg = wire.encode_match(kl)
            service_s.append(_best(lambda: wire.handle_request(shard, msg), svc_iters))
        total_keys = n_threads * per * len(keys)
        cells.append(
            {
                "n_shards": n_shards,
                "n_clients": n_threads,
                "chains": n_threads * per,
                "wall_s": dt,
                "wall_keys_per_s": total_keys / dt,
                "shard_service_us": [s * 1e6 for s in service_s],
                "capacity_keys_per_s": len(keys) / max(service_s),
                "served_per_shard": [srv.served for srv in servers],
                "errors": sum(c.stats.errors for c in clients),
                "timeouts": sum(c.stats.timeouts for c in clients),
            }
        )
    return cells


def run(fast: bool = False) -> list[tuple]:
    n_tokens = 2048 if fast else 15000
    lay = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)
    pool = BelugaPool(lay, 65536, 32, backing="meta")
    idx = GlobalIndex(pool)
    ring = ShmRing(n_slots=64, payload_bytes=1 << 16)
    server = CxlRpcServer(
        ring, wire.make_index_handler(idx, max_reply=ring.payload_bytes)
    ).start()
    client = CxlRpcClient(ring)
    proxy = wire.RpcIndexClient(client, block_tokens=lay.block_tokens)
    results: dict = {"fast": fast, "n_tokens": n_tokens}
    rows = []
    try:
        tokens = list(range(n_tokens))
        keys = proxy.keys_for(tokens)
        n_keys = len(keys)
        results["n_keys"] = n_keys
        blocks = pool.allocate(n_keys)
        epochs = pool.write_blocks(blocks)

        # --- publish: per-key RPCs vs one batched message ---------------
        per_iters = 2 if fast else 3
        def publish_per_key():
            for k, b, e in zip(keys, blocks, epochs):
                proxy.publish_many([k], [b], [e], lay.block_tokens)

        per_key_pub_s = _best(publish_per_key, per_iters)
        batched_pub_s = _best(
            lambda: proxy.publish_many(keys, blocks, epochs, lay.block_tokens),
            8 if fast else 16,
        )
        results["publish"] = {
            "per_key_keys_per_s": n_keys / per_key_pub_s,
            "batched_keys_per_s": n_keys / batched_pub_s,
            "speedup": per_key_pub_s / batched_pub_s,
        }

        # --- match_prefix: QD=1 RTT + batched vs per-key ----------------
        one_key = keys[:1]
        for _ in range(50):  # warm
            proxy.match_prefix_keys(one_key)
        rtt_s = _best(lambda: proxy.match_prefix_keys(one_key), 200 if fast else 400)
        results["match_rtt_us_qd1"] = rtt_s * 1e6

        def match_per_key():
            for k in keys:
                proxy.match_prefix_keys([k])

        per_key_match_s = _best(match_per_key, per_iters)
        batched_match_s = _best(
            lambda: proxy.match_prefix_keys(keys), 8 if fast else 16
        )
        # middle point: 937 single-key ops in ONE ring trip (OP_BATCH) —
        # amortizes the round-trip but not the per-op decode
        one_key_msgs = [wire.encode_match([k]) for k in keys]
        op_batch_s = _best(lambda: proxy.call_batch(one_key_msgs), 4 if fast else 8)
        results["match"] = {
            "chain_rtt_us": batched_match_s * 1e6,
            "per_key_keys_per_s": n_keys / per_key_match_s,
            "op_batch_keys_per_s": n_keys / op_batch_s,
            "batched_keys_per_s": n_keys / batched_match_s,
            "speedup": per_key_match_s / batched_match_s,
        }

        # --- multi-threaded batched-match throughput --------------------
        n_threads, per = (4, 20) if fast else (8, 50)

        def worker():
            for _ in range(per):
                proxy.match_prefix_keys(keys)

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        t0 = time.perf_counter()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        dt = time.perf_counter() - t0
        results["threaded"] = {
            "n_threads": n_threads,
            "chains_per_s": n_threads * per / dt,
            "keys_per_s": n_threads * per * n_keys / dt,
        }
        results["modeled_rtt_us"] = {
            "cxl": DEFAULT.cxl_rpc_rtt * 1e6,
            "rdma_rc": DEFAULT.rdma_rc_rpc_rtt * 1e6,
            "rdma_ud": DEFAULT.rdma_ud_rpc_rtt * 1e6,
        }
        # failed round-trips are NOT invisible: errors/timeouts counted,
        # their wait included in the average (RpcStats satellite)
        results["client_stats"] = {
            "requests_ok": client.stats.requests,
            "errors": client.stats.errors,
            "timeouts": client.stats.timeouts,
            "avg_wait_us": client.stats.avg_wait() * 1e6,
        }
    finally:
        server.stop()

    out_path = OUT_PATH_FAST if fast else OUT_PATH
    # checkpoint the single-ring sections NOW: the sweep below spins up
    # 12 rings under thread load, and a failure there must not discard
    # the results already measured (the file is rewritten, with the
    # sweep folded in, once it completes)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)

    # shard sweep AFTER the single-ring server stopped (its spin thread
    # would steal interpreter time from the sweep's service threads).
    # Always paper-scale chains: a 128-key fast-mode chain leaves 32-key
    # sub-chains whose fixed per-message overhead buries the scaling the
    # sweep exists to measure; --fast trims iteration counts instead.
    results["shard_sweep"] = shard_sweep(15000, fast)

    m, p = results["match"], results["publish"]
    rows.append(
        ("exp11.match_prefix_rtt_qd1", f"{results['match_rtt_us_qd1']:.1f}",
         f"1-key index op over shm ring; paper-modeled "
         f"rtt={DEFAULT.cxl_rpc_rtt*1e6:.2f}us")
    )
    rows.append(
        ("exp11.match_prefix_chain", f"{m['chain_rtt_us']:.1f}",
         f"{results['n_keys']}keys/1rpc;batched={m['batched_keys_per_s']:.0f}keys/s;"
         f"per_key={m['per_key_keys_per_s']:.0f}keys/s;"
         f"op_batch={m['op_batch_keys_per_s']:.0f}keys/s;"
         f"speedup={m['speedup']:.1f}x")
    )
    rows.append(
        ("exp11.publish_many_chain", f"{1e6 * results['n_keys'] / p['batched_keys_per_s']:.1f}",
         f"batched={p['batched_keys_per_s']:.0f}keys/s;"
         f"per_key={p['per_key_keys_per_s']:.0f}keys/s;speedup={p['speedup']:.1f}x")
    )
    t = results["threaded"]
    rows.append(
        ("exp11.threaded_match", f"{1e6 / t['chains_per_s']:.1f}",
         f"{t['n_threads']}threads;{t['keys_per_s']/1e6:.2f}Mkeys/s "
         f"(1-core host; paper: 12.13Mops @QD=128)")
    )
    rows.append(
        ("exp11.modeled_rtt_comparison", f"{DEFAULT.cxl_rpc_rtt*1e6:.2f}",
         f"cxl=2.11us vs rdma_rc={DEFAULT.rdma_rc_rpc_rtt*1e6:.2f}us "
         f"vs rdma_ud={DEFAULT.rdma_ud_rpc_rtt*1e6:.2f}us (4.0x, Fig. 15)")
    )
    cs = results["client_stats"]
    rows.append(
        ("exp11.client_accounting", f"{cs['avg_wait_us']:.1f}",
         f"requests_ok={cs['requests_ok']};errors={cs['errors']};"
         f"timeouts={cs['timeouts']} (failed round-trips counted + waited)")
    )
    by_s = {c["n_shards"]: c for c in results["shard_sweep"]}
    for s, c in sorted(by_s.items()):
        rows.append(
            (f"exp11.shard_sweep.s{s}",
             f"{1e6 * c['wall_s'] / c['chains']:.1f}",
             f"wall={c['wall_keys_per_s']:.0f}keys/s;"
             f"capacity={c['capacity_keys_per_s']:.0f}keys/s;"
             f"bottleneck_service_us={max(c['shard_service_us']):.0f};"
             f"clients={c['n_clients']};errors={c['errors']}")
        )
    cap_x = by_s[4]["capacity_keys_per_s"] / by_s[1]["capacity_keys_per_s"]
    wall_x = by_s[4]["wall_keys_per_s"] / by_s[1]["wall_keys_per_s"]
    results["shard_scaling_s4_vs_s1"] = {"capacity": cap_x, "wall": wall_x}
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    rows.append(
        ("exp11.shard_scaling", f"{cap_x:.2f}",
         f"S4/S1 capacity={cap_x:.2f}x (>=1.5x floor);wall={wall_x:.2f}x "
         f"(all service threads share one GIL on this host)")
    )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI-sized inputs")
    args = ap.parse_args()
    emit(run(fast=args.fast))
    print(f"# wrote {OUT_PATH_FAST if args.fast else OUT_PATH}")

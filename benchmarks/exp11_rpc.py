"""Exp #11 (Fig. 15): CXL-RPC metadata plane — REAL index ops over the ring.

The PR-1/PR-2 version of this harness measured the shared-memory ring
against a toy echo handler; this one serves the actual ``GlobalIndex``
through the ``repro.core.wire`` binary codec, so the numbers are for the
traffic every request really generates:

  * ``match_prefix`` RTT at QD=1 for a paper-scale chain (15k tokens /
    937 keys) in ONE framed message;
  * batched vs per-key ops/s: the same chain shipped as one message (and
    as one OP_BATCH of single-key ops) against 937 individual RPCs — the
    client-side batching path must win by well over the 5x floor;
  * ``publish_many`` batched vs per-key;
  * multi-threaded client throughput over one ring;
  * the paper-calibrated CXL vs RDMA RTT constants alongside (Fig. 15).

Writes ``BENCH_rpc.json`` (``BENCH_rpc.fast.json`` with --fast).

    PYTHONPATH=src python -m benchmarks.exp11_rpc [--fast]
"""

from __future__ import annotations

import json
import threading
import time

from benchmarks.common import emit
from repro.core import wire
from repro.core.fabric import DEFAULT
from repro.core.index import GlobalIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.rpc import CxlRpcClient, CxlRpcServer, ShmRing

OUT_PATH = "BENCH_rpc.json"
OUT_PATH_FAST = "BENCH_rpc.fast.json"


def _best(fn, iters: int, repeat: int = 3) -> float:
    """Seconds per call (best of ``repeat`` runs)."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def run(fast: bool = False) -> list[tuple]:
    n_tokens = 2048 if fast else 15000
    lay = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)
    pool = BelugaPool(lay, 65536, 32, backing="meta")
    idx = GlobalIndex(pool)
    ring = ShmRing(n_slots=64, payload_bytes=1 << 16)
    server = CxlRpcServer(
        ring, wire.make_index_handler(idx, max_reply=ring.payload_bytes)
    ).start()
    client = CxlRpcClient(ring)
    proxy = wire.RpcIndexClient(client, block_tokens=lay.block_tokens)
    results: dict = {"fast": fast, "n_tokens": n_tokens}
    rows = []
    try:
        tokens = list(range(n_tokens))
        keys = proxy.keys_for(tokens)
        n_keys = len(keys)
        results["n_keys"] = n_keys
        blocks = pool.allocate(n_keys)
        epochs = pool.write_blocks(blocks)

        # --- publish: per-key RPCs vs one batched message ---------------
        per_iters = 2 if fast else 3
        def publish_per_key():
            for k, b, e in zip(keys, blocks, epochs):
                proxy.publish_many([k], [b], [e], lay.block_tokens)

        per_key_pub_s = _best(publish_per_key, per_iters)
        batched_pub_s = _best(
            lambda: proxy.publish_many(keys, blocks, epochs, lay.block_tokens),
            8 if fast else 16,
        )
        results["publish"] = {
            "per_key_keys_per_s": n_keys / per_key_pub_s,
            "batched_keys_per_s": n_keys / batched_pub_s,
            "speedup": per_key_pub_s / batched_pub_s,
        }

        # --- match_prefix: QD=1 RTT + batched vs per-key ----------------
        one_key = keys[:1]
        for _ in range(50):  # warm
            proxy.match_prefix_keys(one_key)
        rtt_s = _best(lambda: proxy.match_prefix_keys(one_key), 200 if fast else 400)
        results["match_rtt_us_qd1"] = rtt_s * 1e6

        def match_per_key():
            for k in keys:
                proxy.match_prefix_keys([k])

        per_key_match_s = _best(match_per_key, per_iters)
        batched_match_s = _best(
            lambda: proxy.match_prefix_keys(keys), 8 if fast else 16
        )
        # middle point: 937 single-key ops in ONE ring trip (OP_BATCH) —
        # amortizes the round-trip but not the per-op decode
        one_key_msgs = [wire.encode_match([k]) for k in keys]
        op_batch_s = _best(lambda: proxy.call_batch(one_key_msgs), 4 if fast else 8)
        results["match"] = {
            "chain_rtt_us": batched_match_s * 1e6,
            "per_key_keys_per_s": n_keys / per_key_match_s,
            "op_batch_keys_per_s": n_keys / op_batch_s,
            "batched_keys_per_s": n_keys / batched_match_s,
            "speedup": per_key_match_s / batched_match_s,
        }

        # --- multi-threaded batched-match throughput --------------------
        n_threads, per = (4, 20) if fast else (8, 50)

        def worker():
            for _ in range(per):
                proxy.match_prefix_keys(keys)

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        t0 = time.perf_counter()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        dt = time.perf_counter() - t0
        results["threaded"] = {
            "n_threads": n_threads,
            "chains_per_s": n_threads * per / dt,
            "keys_per_s": n_threads * per * n_keys / dt,
        }
        results["modeled_rtt_us"] = {
            "cxl": DEFAULT.cxl_rpc_rtt * 1e6,
            "rdma_rc": DEFAULT.rdma_rc_rpc_rtt * 1e6,
            "rdma_ud": DEFAULT.rdma_ud_rpc_rtt * 1e6,
        }
    finally:
        server.stop()

    with open(OUT_PATH_FAST if fast else OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)

    m, p = results["match"], results["publish"]
    rows.append(
        ("exp11.match_prefix_rtt_qd1", f"{results['match_rtt_us_qd1']:.1f}",
         f"1-key index op over shm ring; paper-modeled "
         f"rtt={DEFAULT.cxl_rpc_rtt*1e6:.2f}us")
    )
    rows.append(
        ("exp11.match_prefix_chain", f"{m['chain_rtt_us']:.1f}",
         f"{results['n_keys']}keys/1rpc;batched={m['batched_keys_per_s']:.0f}keys/s;"
         f"per_key={m['per_key_keys_per_s']:.0f}keys/s;"
         f"op_batch={m['op_batch_keys_per_s']:.0f}keys/s;"
         f"speedup={m['speedup']:.1f}x")
    )
    rows.append(
        ("exp11.publish_many_chain", f"{1e6 * results['n_keys'] / p['batched_keys_per_s']:.1f}",
         f"batched={p['batched_keys_per_s']:.0f}keys/s;"
         f"per_key={p['per_key_keys_per_s']:.0f}keys/s;speedup={p['speedup']:.1f}x")
    )
    t = results["threaded"]
    rows.append(
        ("exp11.threaded_match", f"{1e6 / t['chains_per_s']:.1f}",
         f"{t['n_threads']}threads;{t['keys_per_s']/1e6:.2f}Mkeys/s "
         f"(1-core host; paper: 12.13Mops @QD=128)")
    )
    rows.append(
        ("exp11.modeled_rtt_comparison", f"{DEFAULT.cxl_rpc_rtt*1e6:.2f}",
         f"cxl=2.11us vs rdma_rc={DEFAULT.rdma_rc_rpc_rtt*1e6:.2f}us "
         f"vs rdma_ud={DEFAULT.rdma_ud_rpc_rtt*1e6:.2f}us (4.0x, Fig. 15)")
    )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI-sized inputs")
    args = ap.parse_args()
    emit(run(fast=args.fast))
    print(f"# wrote {OUT_PATH_FAST if args.fast else OUT_PATH}")

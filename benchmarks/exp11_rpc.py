"""Exp #11 (Fig. 15): RPC — CXL shared-memory ring vs RDMA-RC/UD.

Measures the REAL shared-memory ring (threads on this host) for ping-pong
RTT at QD=1 and throughput at high QD, and reports the paper-calibrated
fabric numbers alongside (this container's core count limits the measured
throughput; the protocol and data structures are the real thing).
"""

import threading
import time

from benchmarks.common import emit
from repro.core.fabric import DEFAULT
from repro.core.rpc import CxlRpcClient, CxlRpcServer, ShmRing


def run(n_warm: int = 50, n_iter: int = 400) -> list[tuple]:
    rows = []
    ring = ShmRing(n_slots=128, payload_bytes=64)
    server = CxlRpcServer(ring, handler=lambda b: b).start()
    client = CxlRpcClient(ring)
    try:
        for _ in range(n_warm):
            client.call(b"warm")
        t0 = time.perf_counter()
        for _ in range(n_iter):
            client.call(b"ping")
        dt = time.perf_counter() - t0
        rtt_us = dt / n_iter * 1e6
        rows.append(
            ("exp11.cxl_rpc_qd1_measured", f"{rtt_us:.1f}",
             f"shm ring on this host; paper-modeled={DEFAULT.cxl_rpc_rtt*1e6:.2f}us")
        )

        # QD=16 throughput with client threads
        n_threads, per = 8, 100
        done = []

        def worker():
            for _ in range(per):
                client.call(b"tp")

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        t0 = time.perf_counter()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        dt = time.perf_counter() - t0
        mops = n_threads * per / dt / 1e6
        rows.append(
            ("exp11.cxl_rpc_qd8_throughput", f"{dt/ (n_threads*per) *1e6:.1f}",
             f"{mops:.3f}Mops measured (1-core host); paper: 12.13Mops @QD=128")
        )
    finally:
        server.stop()

    rows.append(
        ("exp11.modeled_rtt_comparison", f"{DEFAULT.cxl_rpc_rtt*1e6:.2f}",
         f"cxl=2.11us vs rdma_rc={DEFAULT.rdma_rc_rpc_rtt*1e6:.2f}us "
         f"vs rdma_ud={DEFAULT.rdma_ud_rpc_rtt*1e6:.2f}us (4.0x, Fig. 15)")
    )
    return rows


if __name__ == "__main__":
    emit(run())

"""Exp #11 (Fig. 15): CXL-RPC metadata plane — REAL index ops over the ring.

The PR-1/PR-2 version of this harness measured the shared-memory ring
against a toy echo handler; this one serves the actual ``GlobalIndex``
through the ``repro.core.wire`` binary codec, so the numbers are for the
traffic every request really generates:

  * ``match_prefix`` RTT at QD=1 for a paper-scale chain (15k tokens /
    937 keys) in ONE framed message;
  * batched vs per-key ops/s: the same chain shipped as one message (and
    as one OP_BATCH of single-key ops) against 937 individual RPCs — the
    client-side batching path must win by well over the 5x floor;
  * ``publish_many`` batched vs per-key;
  * multi-threaded client throughput over one ring;
  * the paper-calibrated CXL vs RDMA RTT constants alongside (Fig. 15);
  * the SHARD SWEEP, for BOTH ring transports: the same multi-client
    batched-match load against a metadata plane sharded S in {1,2,4}
    ways (S rings, ``ShardedRpcIndexClient`` posting to every ring
    before collecting), once with S service THREADS in this interpreter
    and once with S service PROCESSES over shared-memory rings
    (``repro.core.procserver`` — the paper's deployment, where the
    metadata service owns its cores).  Two numbers per cell: wall keys/s
    (thread mode is GIL-capped; process mode scales with S on multi-core
    hosts, client-side capped on this 2-core container) and CAPACITY
    keys/s = chain keys / bottleneck-shard service demand, each shard's
    sub-chain handler timed single-threaded and contention-free — the
    throughput the same shard layout sustains when each metadata service
    owns a core (the paper's §6 shape).

Client-side ``RpcStats`` (requests / errors / timeouts, with failed
round-trips' wait time included in the average) are surfaced per section.

Writes ``BENCH_rpc.json`` (``BENCH_rpc.fast.json`` with --fast).

    PYTHONPATH=src python -m benchmarks.exp11_rpc [--fast]
"""

from __future__ import annotations

import json
import threading
import time

from benchmarks.common import emit
from repro.core import wire
from repro.core.fabric import DEFAULT
from repro.core.index import GlobalIndex, ShardedIndex
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.rpc import CxlRpcClient, CxlRpcServer, ShmRing

OUT_PATH = "BENCH_rpc.json"
OUT_PATH_FAST = "BENCH_rpc.fast.json"


def _best(fn, iters: int, repeat: int = 3) -> float:
    """Seconds per call (best of ``repeat`` runs)."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def shard_sweep(
    n_tokens: int,
    fast: bool,
    transport: str = "thread",
    shard_counts: tuple = (1, 2, 4),
) -> list[dict]:
    """Multi-client batched-match throughput vs metadata shard count,
    for EITHER ring transport.

    ``transport="thread"``: S service threads in THIS interpreter (the
    PR-4 shape).  Wall aggregate is then GIL-capped near the 1-thread
    rate regardless of S — a ceiling the paper's deployment does not
    have.  ``transport="process"``: each shard's ring lives in a named
    shared-memory segment served by its OWN OS process
    (``repro.core.procserver``), so the service side really scales with
    cores and wall keys/s finally tracks S on a multi-core host (on a
    2-core container the client interpreter itself becomes the cap).

    Two throughput numbers per cell:

      * ``wall_keys_per_s`` — real threaded clients against real rings,
        whatever this host's cores/GIL allow;
      * ``capacity_keys_per_s`` — chain keys / BOTTLENECK-shard service
        demand, DIRECT-MEASURED by the service itself: the OP_STATS
        busy-ns timer (accounted inside ``drain_ready``, thread and
        process transports alike) is snapshotted around a
        single-threaded, contention-free run of each shard's sub-chain
        after the load run.  No in-process replica is built — the
        number comes from the same handler the load hit.  Service
        demand is a property of the shard LAYOUT, not the transport:
        this is the plane's sustainable rate once each service owns a
        core, the number the >=1.5x S=4 scaling floor is about.
    """
    from repro.core.index import partition_keys

    lay = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)
    n_threads, per = (4, 10) if fast else (8, 30)
    svc_iters = 20 if fast else 50
    cells = []
    for n_shards in shard_counts:
        pool = BelugaPool(lay, 65536, 32, backing="meta")
        servers = []
        shared_hasher = None
        on_freed = None
        if transport == "thread":
            sidx = ShardedIndex(pool, n_shards)
            shared_hasher = sidx.hasher
            clients = []
            for shard in sidx.shards:
                ring = ShmRing(n_slots=64, payload_bytes=1 << 16)
                servers.append(
                    CxlRpcServer(
                        ring,
                        wire.make_index_handler(
                            shard, max_reply=ring.payload_bytes
                        ),
                    ).start()
                )
                clients.append(CxlRpcClient(ring))
        elif transport == "process":
            from repro.core.procserver import ProcessRpcServer

            spec = pool.share_meta()
            servers = [
                ProcessRpcServer(spec, n_slots=64, payload_bytes=1 << 16).start()
                for _ in range(n_shards)
            ]
            clients = [
                CxlRpcClient(srv.ring, liveness=srv.alive) for srv in servers
            ]
            on_freed = pool.release  # deferred cross-process reclaim
        else:
            raise ValueError(transport)
        try:
            proxy = wire.ShardedRpcIndexClient(
                clients, lay.block_tokens, hasher=shared_hasher,
                on_freed=on_freed,
            )
            keys = proxy.keys_for(list(range(n_tokens)))
            blocks = pool.allocate(len(keys))
            proxy.publish_many(list(keys), blocks, pool.write_blocks(blocks), 16)
            for _ in range(5):  # warm (LRU fast path, caches)
                proxy.match_prefix_keys(keys)

            def worker():
                p = wire.ShardedRpcIndexClient(
                    clients, lay.block_tokens, hasher=proxy.hasher,
                    on_freed=on_freed,
                )
                for _ in range(per):
                    p.match_prefix_keys(keys)

            ts = [threading.Thread(target=worker) for _ in range(n_threads)]
            t0 = time.perf_counter()
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            dt = time.perf_counter() - t0
            served = [srv.served for srv in servers]
            errors = sum(c.stats.errors for c in clients)
            timeouts = sum(c.stats.timeouts for c in clients)
            # per-shard service demand direct-measured in the service:
            # busy-ns delta around a single-threaded run of each shard's
            # sub-chain (the replica the old harness rebuilt is gone —
            # the handler that served the load times itself)
            key_lists, _ = partition_keys(keys, n_shards)
            service_s = []
            for srv, cl, kl in zip(servers, clients, key_lists):
                msg = wire.encode_match(kl)
                cl.call(msg)  # warm: fault in code paths outside the timer
                b0 = srv.busy_ns
                for _ in range(svc_iters):
                    cl.call(msg)
                service_s.append((srv.busy_ns - b0) / svc_iters / 1e9)
        finally:
            for srv in servers:
                srv.close()  # spin threads/processes would skew timing
            pool.unshare_meta()
        total_keys = n_threads * per * len(keys)
        cells.append(
            {
                "transport": transport,
                "n_shards": n_shards,
                "n_clients": n_threads,
                "chains": n_threads * per,
                "wall_s": dt,
                "wall_keys_per_s": total_keys / dt,
                "shard_service_us": [s * 1e6 for s in service_s],
                "capacity_keys_per_s": len(keys) / max(service_s),
                "served_per_shard": served,
                "errors": errors,
                "timeouts": timeouts,
            }
        )
    return cells


def chaos_sweep(n_tokens: int, fast: bool, n_shards: int = 2) -> dict:
    """Kill -9 one supervised metadata shard under live match load and
    measure the service through the kill -> journal rebuild -> adopt_ring
    window vs steady state.

    The plane is the self-healing deployment: one ``ShardSupervisor``
    per shard (crash probe + fresh-ring respawn + journal replay),
    clients with bounded retry AND ``degrade=True`` — so every chain
    issued during the outage still RETURNS (holes for the dead shard's
    positions at worst, a retried full hit once the supervisor swears
    the shard back in).  Reported:

      * steady-state keys/s (pre-kill, single client, wall);
      * outage-window keys/s — matched keys actually returned between
        the kill and the first full-length match (lower: holes + retry
        backoff), over that window's wall time;
      * ``recovery_s`` — kill to first full-length match (detection +
        respawn + journal replay + cut-over + one successful op);
      * restart/retry/degraded counters, and the journal size replayed.
    """
    from repro.core.procserver import ShardSupervisor
    from repro.core.rpc import RetryPolicy

    lay = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)
    pool = BelugaPool(lay, 65536, 32, backing="meta")
    spec = pool.share_meta()
    sups = [
        ShardSupervisor(
            spec, journal_capacity=65536, probe_interval=0.01,
            n_slots=64, payload_bytes=1 << 16,
        ).start()
        for _ in range(n_shards)
    ]
    clients = []
    for sup in sups:
        cl = CxlRpcClient(sup.ring, liveness=sup.server.alive)
        sup.register_client(cl)
        clients.append(cl)
    proxy = wire.ShardedRpcIndexClient(
        clients, lay.block_tokens, on_freed=pool.release,
        journals=[s.journal for s in sups],
        retry=RetryPolicy(), degrade=True,
    )
    try:
        for sup in sups:
            if not sup.wait_ready(10):
                raise RuntimeError("shard service never became ready")
        keys = proxy.keys_for(list(range(n_tokens)))
        blocks = pool.allocate(len(keys))
        proxy.publish_many(list(keys), blocks, pool.write_blocks(blocks), 16)
        for _ in range(5):
            proxy.match_prefix_keys(keys)
        # steady state
        iters = 20 if fast else 80
        t0 = time.perf_counter()
        for _ in range(iters):
            proxy.match_prefix_keys(keys)
        steady_s = (time.perf_counter() - t0) / iters
        # chaos window: kill shard 0, keep matching until fully healed
        t_kill = time.perf_counter()
        sups[0].kill()
        matched = 0
        chains = 0
        recovery_s = None
        while time.perf_counter() - t_kill < 30.0:
            hits = proxy.match_prefix_keys(keys)
            chains += 1
            matched += len(hits)
            if len(hits) == len(keys):
                recovery_s = time.perf_counter() - t_kill
                break
        window_s = time.perf_counter() - t_kill
        # post-recovery steady state (the rebuilt shard serves the same
        # entries: journal replay restored every confirmed publish)
        t0 = time.perf_counter()
        for _ in range(iters):
            proxy.match_prefix_keys(keys)
        post_s = (time.perf_counter() - t0) / iters
        return {
            "n_shards": n_shards,
            "n_keys": len(keys),
            "steady_keys_per_s": len(keys) / steady_s,
            "outage_keys_per_s": matched / window_s,
            "outage_chains": chains,
            "recovery_s": recovery_s,
            "post_recovery_keys_per_s": len(keys) / post_s,
            "restarts": sum(s.restarts for s in sups),
            "rpc_retries": sum(c.stats.retries for c in clients),
            "rpc_degraded_ops": sum(c.stats.degraded_ops for c in clients),
            "journal_records": [len(s.journal) for s in sups],
        }
    finally:
        for sup in sups:
            sup.close()
        pool.unshare_meta()


def run(fast: bool = False) -> list[tuple]:
    n_tokens = 2048 if fast else 15000
    lay = PoolLayout(block_tokens=16, n_layers_kv=4, n_kv_heads=2, head_dim=8)
    pool = BelugaPool(lay, 65536, 32, backing="meta")
    idx = GlobalIndex(pool)
    ring = ShmRing(n_slots=64, payload_bytes=1 << 16)
    server = CxlRpcServer(
        ring, wire.make_index_handler(idx, max_reply=ring.payload_bytes)
    ).start()
    client = CxlRpcClient(ring)
    proxy = wire.RpcIndexClient(client, block_tokens=lay.block_tokens)
    results: dict = {"fast": fast, "n_tokens": n_tokens}
    rows = []
    try:
        tokens = list(range(n_tokens))
        keys = proxy.keys_for(tokens)
        n_keys = len(keys)
        results["n_keys"] = n_keys
        blocks = pool.allocate(n_keys)
        epochs = pool.write_blocks(blocks)

        # --- publish: per-key RPCs vs one batched message ---------------
        per_iters = 2 if fast else 3
        def publish_per_key():
            for k, b, e in zip(keys, blocks, epochs):
                proxy.publish_many([k], [b], [e], lay.block_tokens)

        per_key_pub_s = _best(publish_per_key, per_iters)
        batched_pub_s = _best(
            lambda: proxy.publish_many(keys, blocks, epochs, lay.block_tokens),
            8 if fast else 16,
        )
        results["publish"] = {
            "per_key_keys_per_s": n_keys / per_key_pub_s,
            "batched_keys_per_s": n_keys / batched_pub_s,
            "speedup": per_key_pub_s / batched_pub_s,
        }

        # --- match_prefix: QD=1 RTT + batched vs per-key ----------------
        one_key = keys[:1]
        for _ in range(50):  # warm
            proxy.match_prefix_keys(one_key)
        rtt_s = _best(lambda: proxy.match_prefix_keys(one_key), 200 if fast else 400)
        results["match_rtt_us_qd1"] = rtt_s * 1e6

        def match_per_key():
            for k in keys:
                proxy.match_prefix_keys([k])

        per_key_match_s = _best(match_per_key, per_iters)
        batched_match_s = _best(
            lambda: proxy.match_prefix_keys(keys), 8 if fast else 16
        )
        # middle point: 937 single-key ops in ONE ring trip (OP_BATCH) —
        # amortizes the round-trip but not the per-op decode
        one_key_msgs = [wire.encode_match([k]) for k in keys]
        op_batch_s = _best(lambda: proxy.call_batch(one_key_msgs), 4 if fast else 8)
        results["match"] = {
            "chain_rtt_us": batched_match_s * 1e6,
            "per_key_keys_per_s": n_keys / per_key_match_s,
            "op_batch_keys_per_s": n_keys / op_batch_s,
            "batched_keys_per_s": n_keys / batched_match_s,
            "speedup": per_key_match_s / batched_match_s,
        }

        # --- multi-threaded batched-match throughput --------------------
        n_threads, per = (4, 20) if fast else (8, 50)

        def worker():
            for _ in range(per):
                proxy.match_prefix_keys(keys)

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        t0 = time.perf_counter()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        dt = time.perf_counter() - t0
        results["threaded"] = {
            "n_threads": n_threads,
            "chains_per_s": n_threads * per / dt,
            "keys_per_s": n_threads * per * n_keys / dt,
        }
        results["modeled_rtt_us"] = {
            "cxl": DEFAULT.cxl_rpc_rtt * 1e6,
            "rdma_rc": DEFAULT.rdma_rc_rpc_rtt * 1e6,
            "rdma_ud": DEFAULT.rdma_ud_rpc_rtt * 1e6,
        }
        # failed round-trips are NOT invisible: errors/timeouts counted,
        # their wait included in the average (RpcStats satellite)
        results["client_stats"] = {
            "requests_ok": client.stats.requests,
            "errors": client.stats.errors,
            "timeouts": client.stats.timeouts,
            "avg_wait_us": client.stats.avg_wait() * 1e6,
        }
    finally:
        server.stop()

    out_path = OUT_PATH_FAST if fast else OUT_PATH
    # checkpoint the single-ring sections NOW: the sweep below spins up
    # 12 rings under thread load, and a failure there must not discard
    # the results already measured (the file is rewritten, with the
    # sweep folded in, once it completes)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)

    # shard sweep AFTER the single-ring server stopped (its spin thread
    # would steal interpreter time from the sweep's service threads).
    # Always paper-scale chains: a 128-key fast-mode chain leaves 32-key
    # sub-chains whose fixed per-message overhead buries the scaling the
    # sweep exists to measure; --fast trims iteration counts instead.
    results["shard_sweep"] = shard_sweep(15000, fast, transport="thread")
    # ... and the SAME sweep with one metadata service PROCESS per shard
    # (shared-memory rings): the deployment where wall keys/s is allowed
    # to track S because service work leaves this interpreter's GIL
    results["shard_sweep_process"] = shard_sweep(
        15000, fast, transport="process"
    )
    # chaos sweep: kill -9 one SUPERVISED shard under load, measure the
    # kill -> journal rebuild -> adopt window vs steady state (always
    # paper-scale chains, like the shard sweep; --fast trims iterations)
    results["chaos"] = chaos_sweep(15000, fast)

    m, p = results["match"], results["publish"]
    rows.append(
        ("exp11.match_prefix_rtt_qd1", f"{results['match_rtt_us_qd1']:.1f}",
         f"1-key index op over shm ring; paper-modeled "
         f"rtt={DEFAULT.cxl_rpc_rtt*1e6:.2f}us")
    )
    rows.append(
        ("exp11.match_prefix_chain", f"{m['chain_rtt_us']:.1f}",
         f"{results['n_keys']}keys/1rpc;batched={m['batched_keys_per_s']:.0f}keys/s;"
         f"per_key={m['per_key_keys_per_s']:.0f}keys/s;"
         f"op_batch={m['op_batch_keys_per_s']:.0f}keys/s;"
         f"speedup={m['speedup']:.1f}x")
    )
    rows.append(
        ("exp11.publish_many_chain", f"{1e6 * results['n_keys'] / p['batched_keys_per_s']:.1f}",
         f"batched={p['batched_keys_per_s']:.0f}keys/s;"
         f"per_key={p['per_key_keys_per_s']:.0f}keys/s;speedup={p['speedup']:.1f}x")
    )
    t = results["threaded"]
    rows.append(
        ("exp11.threaded_match", f"{1e6 / t['chains_per_s']:.1f}",
         f"{t['n_threads']}threads;{t['keys_per_s']/1e6:.2f}Mkeys/s "
         f"(1-core host; paper: 12.13Mops @QD=128)")
    )
    rows.append(
        ("exp11.modeled_rtt_comparison", f"{DEFAULT.cxl_rpc_rtt*1e6:.2f}",
         f"cxl=2.11us vs rdma_rc={DEFAULT.rdma_rc_rpc_rtt*1e6:.2f}us "
         f"vs rdma_ud={DEFAULT.rdma_ud_rpc_rtt*1e6:.2f}us (4.0x, Fig. 15)")
    )
    cs = results["client_stats"]
    rows.append(
        ("exp11.client_accounting", f"{cs['avg_wait_us']:.1f}",
         f"requests_ok={cs['requests_ok']};errors={cs['errors']};"
         f"timeouts={cs['timeouts']} (failed round-trips counted + waited)")
    )
    sweeps = {
        "thread": {c["n_shards"]: c for c in results["shard_sweep"]},
        "process": {c["n_shards"]: c for c in results["shard_sweep_process"]},
    }
    for transport, by_s in sweeps.items():
        tag = "shard_sweep" if transport == "thread" else "shard_sweep_process"
        for s, c in sorted(by_s.items()):
            rows.append(
                (f"exp11.{tag}.s{s}",
                 f"{1e6 * c['wall_s'] / c['chains']:.1f}",
                 f"wall={c['wall_keys_per_s']:.0f}keys/s;"
                 f"capacity={c['capacity_keys_per_s']:.0f}keys/s;"
                 f"bottleneck_service_us={max(c['shard_service_us']):.0f};"
                 f"clients={c['n_clients']};errors={c['errors']}")
            )
    results["shard_scaling_s4_vs_s1"] = {
        t: {
            "capacity": by_s[4]["capacity_keys_per_s"]
            / by_s[1]["capacity_keys_per_s"],
            "wall": by_s[4]["wall_keys_per_s"] / by_s[1]["wall_keys_per_s"],
        }
        for t, by_s in sweeps.items()
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    sc = results["shard_scaling_s4_vs_s1"]
    rows.append(
        ("exp11.shard_scaling", f"{sc['thread']['capacity']:.2f}",
         f"S4/S1 capacity={sc['thread']['capacity']:.2f}x (>=1.5x floor);"
         f"wall thread={sc['thread']['wall']:.2f}x (GIL-capped) vs "
         f"process={sc['process']['wall']:.2f}x (service owns its cores; "
         f"client side is the residual cap on few-core hosts)")
    )
    ch = results["chaos"]
    rows.append(
        ("exp11.chaos_recovery", f"{(ch['recovery_s'] or -1) * 1e3:.0f}",
         f"kill->rebuild->recover={ch['recovery_s']:.3f}s;"
         f"steady={ch['steady_keys_per_s']:.0f}keys/s;"
         f"outage={ch['outage_keys_per_s']:.0f}keys/s;"
         f"post={ch['post_recovery_keys_per_s']:.0f}keys/s;"
         f"restarts={ch['restarts']};retries={ch['rpc_retries']};"
         f"degraded={ch['rpc_degraded_ops']}"
         if ch["recovery_s"] is not None
         else "shard NEVER recovered within the 30s chaos window")
    )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI-sized inputs")
    args = ap.parse_args()
    emit(run(fast=args.fast))
    print(f"# wrote {OUT_PATH_FAST if args.fast else OUT_PATH}")

"""Exp #7 (Fig. 12): sensitivity to input context length (2K/4K/8K/15K).

Paper finding: Beluga's edge grows with context length (KV read/write time
is a larger share of end-to-end latency).
"""

from benchmarks.common import emit, qwen32b_layout, run_populate_then_hit
from repro.serving.scheduler import ClusterConfig


def run() -> list[tuple]:
    layout = qwen32b_layout()
    rows = []
    gains = []
    for in_len in (2048, 4096, 8192, 15000):
        res = {}
        for mode, sbt in [("rdma", 256), ("beluga", 0)]:
            cfg = ClusterConfig(
                n_engines=16, transfer_mode=mode, pool_blocks=262144,
                super_block_tokens=sbt,
            )
            _, s2, _ = run_populate_then_hit(cfg, layout, n=128, in_len=in_len)
            res[mode] = s2
            rows.append(
                (f"exp07.{mode}.ctx_{in_len}", f"{s2['avg_ttft_s']*1e6:.0f}",
                 f"ttft={s2['avg_ttft_s']:.2f}s;p99={s2['p99_ttft_s']:.2f}s")
            )
        gain = res["rdma"]["avg_ttft_s"] / max(res["beluga"]["avg_ttft_s"], 1e-9)
        gains.append((in_len, gain))
        rows.append(
            (f"exp07.gain.ctx_{in_len}", f"{gain:.2f}",
             "beluga TTFT speedup over rdma (paper: grows with context)")
        )
    monotone = all(gains[i][1] <= gains[i + 1][1] * 1.15 for i in range(len(gains) - 1))
    rows.append(("exp07.gain_grows_with_context", "0", f"ok={monotone}"))
    return rows


if __name__ == "__main__":
    emit(run())

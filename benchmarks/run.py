"""Benchmark harness entry: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only exp05,exp11] [--fast]
    PYTHONPATH=src python -m benchmarks.run --smoke   # CI: exp11-14 tiny

``--smoke`` runs the four artifact-emitting harnesses (exp11 CXL-RPC
metadata plane — including the shard-scaling sweep, so ``BENCH_rpc.json``
carries per-shard-count rows in CI — exp12 control plane, exp13 tiering,
exp14 zero-copy engine-worker data plane) at CI-sized inputs so the perf
benchmarks can't silently rot; their ``BENCH_*.fast.json`` outputs are
uploaded by the CI job.

Prints ``name,us_per_call,derived`` CSV per row, then a roofline summary
derived from the dry-run artifacts (if present in results/dryrun).
"""

from __future__ import annotations

import argparse
import sys
import time


MODULES = [
    ("exp01", "benchmarks.exp01_coherence"),
    ("exp02", "benchmarks.exp02_latency"),
    ("exp03", "benchmarks.exp03_skew"),
    ("exp04", "benchmarks.exp04_background"),
    ("exp05", "benchmarks.exp05_e2e"),
    ("exp06", "benchmarks.exp06_rates"),
    ("exp07", "benchmarks.exp07_context"),
    ("exp08", "benchmarks.exp08_software"),
    ("exp09", "benchmarks.exp09_dense_transfer"),
    ("exp10", "benchmarks.exp10_sparse"),
    ("exp11", "benchmarks.exp11_rpc"),
    ("exp12", "benchmarks.exp12_control_plane"),
    ("exp13", "benchmarks.exp13_tiering"),
    ("exp14", "benchmarks.exp14_procengine"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated exp ids")
    ap.add_argument("--fast", action="store_true", help="smaller exp05")
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI smoke: tiny-config exp11 + exp12 + exp13 only",
    )
    args = ap.parse_args()
    if args.smoke:
        args.fast = True
        args.only = "exp11,exp12,exp13,exp14"
    only = set(args.only.split(",")) if args.only else None

    import importlib

    print("name,us_per_call,derived")
    failures = []
    for exp_id, mod_name in MODULES:
        if only and exp_id not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            if args.fast and exp_id == "exp05":
                rows = mod.run(n=64, in_len=4096)
            elif exp_id in ("exp11", "exp12", "exp13", "exp14"):
                rows = mod.run(fast=args.fast)
            else:
                rows = mod.run()
            for name, us, derived in rows:
                print(f"{name},{us},{derived}")
            print(f"# {exp_id} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # noqa: BLE001
            failures.append((exp_id, repr(e)))
            print(f"{exp_id}.FAILED,0,{e!r}")

    # roofline summary (from dry-run artifacts, if present)
    try:
        from repro.launch.roofline import load_records, roofline_terms

        rows = [t for r in load_records("results/dryrun") if (t := roofline_terms(r))]
        for r in sorted(rows, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
            bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
            print(
                f"roofline.{r['cell']},{bound*1e6:.0f},"
                f"dominant={r['dominant']};frac={r['roofline_frac']:.3f};"
                f"useful/HLO={r['model_flops_ratio']:.2f}"
            )
    except Exception as e:  # noqa: BLE001
        print(f"roofline.SKIPPED,0,{e!r}")

    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Exp #5 (Table 5): end-to-end LV-Eval — vLLM / +MoonCake(RDMA) / +Beluga.

Closed-loop 256 clients, 16 instances, Qwen3-32B layout. Two phases:
cache-populate (first run) then cache-hit (second run), per the paper.
"""

from benchmarks.common import qwen32b_layout, run_populate_then_hit
from repro.serving.scheduler import ClusterConfig


PAPER = {  # Table 5 (s / req/s)
    "vllm": {"pop_ttft": 18.76, "pop_qps": 0.96, "hit_ttft": 18.23, "hit_qps": 0.96},
    "rdma": {"pop_ttft": 19.66, "pop_qps": 1.02, "hit_ttft": 13.00, "hit_qps": 1.54},
    "beluga": {"pop_ttft": 17.22, "pop_qps": 1.24, "hit_ttft": 1.36, "hit_qps": 11.32},
}


def run(n: int = 256, in_len: int = 15000) -> list[tuple]:
    layout = qwen32b_layout()
    rows = []
    res = {}
    for name, mode, sbt in [
        ("vllm", "none", 0),
        ("rdma", "rdma", 256),
        ("beluga", "beluga", 0),
    ]:
        cfg = ClusterConfig(
            n_engines=16, transfer_mode=mode, pool_blocks=262144,
            super_block_tokens=sbt,
        )
        s1, s2, _ = run_populate_then_hit(cfg, layout, n=n, in_len=in_len)
        res[name] = (s1, s2)
        p = PAPER[name]
        rows.append(
            (f"exp05.{name}.populate", f"{s1['avg_ttft_s']*1e6:.0f}",
             f"ttft={s1['avg_ttft_s']:.2f}s;p99={s1['p99_ttft_s']:.2f};"
             f"tpot={s1['avg_tpot_s']:.3f};qps={s1['qps']:.2f};"
             f"paper_ttft={p['pop_ttft']};paper_qps={p['pop_qps']}")
        )
        rows.append(
            (f"exp05.{name}.cache_hit", f"{s2['avg_ttft_s']*1e6:.0f}",
             f"ttft={s2['avg_ttft_s']:.2f}s;p99={s2['p99_ttft_s']:.2f};"
             f"tpot={s2['avg_tpot_s']:.3f};qps={s2['qps']:.2f};"
             f"paper_ttft={p['hit_ttft']};paper_qps={p['hit_qps']}")
        )
    qps_ratio = res["beluga"][1]["qps"] / res["rdma"][1]["qps"]
    ttft_cut = 1 - res["beluga"][1]["avg_ttft_s"] / res["rdma"][1]["avg_ttft_s"]
    rows.append(
        ("exp05.beluga_vs_rdma", f"{qps_ratio:.2f}",
         f"qps_ratio={qps_ratio:.2f}x(paper 7.35x);"
         f"ttft_cut={100*ttft_cut:.1f}%(paper 89.6%)")
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

"""Exp #8 (Fig. 13): software configurations — PD-disaggregation + block size.

(a) prefill/decode disaggregated: prefill instances write the pool, decode
    instances fetch every context from it — QPS ratio beluga/rdma
    (paper: 3.41x-9.47x).
(b) KVCache block size: RDMA needs 256-token super-blocks; Beluga runs at
    vLLM's native 16 (paper: 13.0s vs 76.8s TTFT for RDMA).
(c) + scheduler policy comparison (paper §6.3): cache-oblivious vs
    cache-aware routing on the shared pool.
"""

from benchmarks.common import emit, lveval_requests, qwen32b_layout, run_populate_then_hit
from repro.serving.request import summarize
from repro.serving.scheduler import Cluster, ClusterConfig


def _pd_disagg(mode: str, sbt: int) -> dict:
    """8 prefill + 8 decode instances: decode always fetches from the pool."""
    layout = qwen32b_layout()
    cfg = ClusterConfig(
        n_engines=8, transfer_mode=mode, pool_blocks=262144,
        super_block_tokens=sbt,
    )
    pre = Cluster(cfg, layout)
    for r in lveval_requests(128, 8192, 1):  # prefill-only phase
        pre.dispatch(r)
    pre.run()
    t0 = max(e.clock for e in pre.engines)
    # decode cluster shares the SAME pool/index
    dec = Cluster(cfg, layout)
    dec.pool = pre.pool
    dec.index = pre.index
    for e in dec.engines:
        e.manager.pool = pre.pool
        e.manager.index = pre.index
        e.manager.transfer.pool = pre.pool
    for r in lveval_requests(128, 8192, 128, tag="d", arrival0=t0):
        dec.dispatch(r)
    dec.run()
    ds = [r for r in dec.requests if r.req_id.startswith("d")]
    return summarize(ds, max(x.t_done for x in ds) - t0)


def run() -> list[tuple]:
    rows = []
    pd = {}
    for mode, sbt in [("rdma", 256), ("beluga", 0)]:
        s = _pd_disagg(mode, sbt)
        pd[mode] = s
        rows.append(
            (f"exp08.pd_disagg.{mode}", f"{s['avg_ttft_s']*1e6:.0f}",
             f"ttft={s['avg_ttft_s']:.2f}s;qps={s['qps']:.2f}")
        )
    ratio = pd["beluga"]["qps"] / max(pd["rdma"]["qps"], 1e-9)
    rows.append(
        ("exp08.pd_qps_ratio", f"{ratio:.2f}", "paper: 3.41x-9.47x")
    )

    # (b) block-size sweep for the RDMA path + beluga at native 16
    layout = qwen32b_layout()
    for name, mode, sbt in [
        ("rdma_block256", "rdma", 256),
        ("rdma_block16", "rdma", 16),
        ("beluga_block16", "beluga", 0),
    ]:
        cfg = ClusterConfig(
            n_engines=16, transfer_mode=mode, pool_blocks=262144,
            super_block_tokens=sbt,
        )
        _, s2, _ = run_populate_then_hit(cfg, layout, n=128, in_len=15000)
        rows.append(
            (f"exp08.blocksize.{name}", f"{s2['avg_ttft_s']*1e6:.0f}",
             f"hit_ttft={s2['avg_ttft_s']:.2f}s "
             f"(paper: rdma256=13.0s rdma16=76.8s beluga=1.36s)")
        )

    # (c) scheduler policy on the shared pool (cache-oblivious wins on load
    # balance; cache-aware skews -- paper §6.3)
    for policy in ("cache_oblivious", "cache_aware", "round_robin"):
        cfg = ClusterConfig(
            n_engines=16, transfer_mode="beluga", pool_blocks=262144,
            policy=policy,
        )
        _, s2, c = run_populate_then_hit(cfg, layout, n=192, in_len=8192)
        loads = [e.stats.busy_s for e in c.engines]
        imb = max(loads) / max(min(loads), 1e-9)
        rows.append(
            (f"exp08.policy.{policy}", f"{s2['avg_ttft_s']*1e6:.0f}",
             f"hit_ttft={s2['avg_ttft_s']:.2f}s;load_imbalance={imb:.2f}x")
        )
    return rows


if __name__ == "__main__":
    emit(run())

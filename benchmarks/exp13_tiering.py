"""Exp #13 (beyond-paper): tiered pool memory under capacity pressure.

Sweeps pool-pressure ratios (working set / fast-tier capacity) and Zipf
skew over a document-reuse workload, comparing:

  * **baseline** — flat PR-1 pool of the same (fast) capacity: on OOM the
    index destroys LRU prefixes (``evict_lru``) and every re-request of a
    destroyed prefix degenerates to full recompute;
  * **tiered**  — same fast capacity plus a spill tier (RDMA-DRAM media)
    with the background migration engine: cold prefixes are demoted ahead
    of pressure and stay fetchable at spill latency.

Protocol per cell: populate every document once, then measure TTFT over a
Zipf-sampled re-request stream.  Requests are dispatched *event-driven*
(fed to the cluster as virtual time reaches their arrival, engines
advancing in lockstep windows) so routing sees live load signals.
(``EngineInstance.submit`` used to be a clock barrier that would have
fast-forwarded every engine clock to the last pre-dispatched arrival;
PR 3 removed it, so open-loop streams no longer inflate TTFT either way.)

The **tier_chain** sweep compares chain DEPTHS at the same fast capacity
and the same (constrained) RDMA-DRAM spill budget: destroy-on-evict
(flat), the 2-tier chain, and the 3-level chain with a deep SSD-class
tier hung below — at >=2x oversubscription the 3-tier chain must beat
destroy-on-evict on avg TTFT (CI-gated from the emitted artifact).

Also runs the **zero-cost check**: a ``tiering=off`` config must reproduce
the PR-1 exp05-small summary stats bit-identically (captured below from
the PR-1 code on this container) — the subsystem must cost nothing when
disabled.

    PYTHONPATH=src python -m benchmarks.exp13_tiering [--fast]

Writes ``BENCH_tiering.json`` (``BENCH_tiering.fast.json`` with --fast).
"""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import qwen32b_layout, run_populate_then_hit
from repro.serving.request import Request, summarize
from repro.serving.scheduler import Cluster, ClusterConfig
from repro.tiering import TieringConfig

OUT_PATH = "BENCH_tiering.json"
OUT_PATH_FAST = "BENCH_tiering.fast.json"

# PR-1 reference for the zero-cost check: run_populate_then_hit with the
# config in zero_cost_check() below, measured on the PR-1 code (flat
# BelugaPool, before the tiering subsystem existed). All virtual-time
# stats — any drift means the disabled subsystem perturbed the sim.
REFERENCE_PR1 = {
    "populate": {
        "n_done": 64,
        "avg_ttft_s": 2.8039488662139376,
        "p99_ttft_s": 7.089036169999989,
        "avg_tpot_s": 0.045259955066344205,
        "p99_tpot_s": 0.05249664386904753,
        "qps": 6.937195787229816,
        "hit_tokens": 38304,
        "total_prompt_tokens": 131072,
    },
    "cache_hit": {
        "n_done": 64,
        "avg_ttft_s": 1.8867119865384638,
        "p99_ttft_s": 5.383646092307693,
        "avg_tpot_s": 0.040713094120116054,
        "p99_tpot_s": 0.04234551245421243,
        "qps": 8.561591044588884,
        "hit_tokens": 131072,
        "total_prompt_tokens": 131072,
    },
}


# ---------------------------------------------------------------------------
def _doc_tokens(d: int, in_len: int) -> list[int]:
    return np.random.default_rng(9000 + d).integers(0, 1000, size=in_len).tolist()


def zipf_docs(n: int, n_docs: int, skew: float, seed: int = 13) -> np.ndarray:
    """Zipf(``skew``) document popularity: hot docs recur quickly, the
    tail recurs slowly — the pattern where LRU destruction hurts most."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_docs + 1, dtype=np.float64)
    p = ranks ** -skew
    p /= p.sum()
    return rng.choice(n_docs, size=n, p=p)


def run_stream(cluster: Cluster, reqs: list[Request], window_s: float = 0.25) -> None:
    """Event-driven driver: dispatch each request as virtual time reaches
    its arrival, advancing all engines in lockstep windows."""
    reqs = sorted(reqs, key=lambda r: r.arrival)
    i, now = 0, min(r.arrival for r in reqs)
    while i < len(reqs) or any(e.has_backlog() for e in cluster.engines):
        while i < len(reqs) and reqs[i].arrival <= now:
            cluster.dispatch(reqs[i])
            i += 1
        backlog = sum(e.n_queued + len(e.running) for e in cluster.engines)
        clocks = [e.clock for e in cluster.engines]
        for e in cluster.engines:
            e.advance(now)
        stalled = (
            i >= len(reqs)
            and backlog
            == sum(e.n_queued + len(e.running) for e in cluster.engines)
            and clocks == [e.clock for e in cluster.engines]
        )
        if stalled and now > max(clocks):
            # no arrivals left, the window passed every engine clock, and
            # nothing moved: drained, or capacity-deadlocked (drain()'s
            # stop condition) — no future event can unblock anything
            break
        now += window_s


# ---------------------------------------------------------------------------
def _round_shards(n: int, shards: int) -> int:
    return max(shards, -(-n // shards) * shards)


def _measure(
    cfg: ClusterConfig,
    layout,
    skew: float,
    n: int,
    n_docs: int,
    in_len: int,
    out_len: int,
    rate: float,
) -> dict:
    """Populate every doc once, then measure TTFT over a Zipf re-request
    stream — one cluster config, the shared protocol of every sweep."""
    c = Cluster(cfg, layout)
    populate = [
        Request(f"p{d}", _doc_tokens(d, in_len), out_len, arrival=0.1 * d)
        for d in range(n_docs)
    ]
    run_stream(c, populate)
    t0 = max(e.clock for e in c.engines)
    rng = np.random.default_rng(17)
    t = t0
    stream = []
    for i, d in enumerate(zipf_docs(n, n_docs, skew).tolist()):
        stream.append(
            Request(f"z{i}", _doc_tokens(d, in_len), out_len, arrival=t)
        )
        t += rng.exponential(1.0 / rate)
    run_stream(c, stream)
    finished = [r.t_done for r in stream if r.t_done is not None]
    span = (max(finished) - t0) if finished else 0.0
    s = summarize(stream, span)
    out = {
        "avg_ttft_s": s["avg_ttft_s"],
        "p99_ttft_s": s["p99_ttft_s"],
        "qps": s["qps"],
        "hit_tokens": s["hit_tokens"],
    }
    if c.migrator is not None:
        out["stats"] = c.pool.stats_dict()
        out["stats"]["migrator_steps"] = c.migrator.steps
    return out


def _base_cfg(fast_blocks: int, shards: int, n_engines: int) -> dict:
    return dict(
        n_engines=n_engines,
        transfer_mode="beluga",
        pool_blocks=fast_blocks,
        pool_shards=shards,
        hbm_slots_per_engine=6750,
    )


def sweep_cell(
    oversub: float,
    skew: float,
    n: int,
    n_docs: int,
    in_len: int,
    out_len: int = 8,
    rate: float = 8.0,
    n_engines: int = 4,
) -> dict:
    layout = qwen32b_layout()
    bt = layout.block_tokens
    working_set = n_docs * (in_len // bt)
    shards = 32
    fast_blocks = _round_shards(int(working_set / oversub), shards)
    spill_blocks = _round_shards(4 * fast_blocks, shards)
    base = _base_cfg(fast_blocks, shards, n_engines)
    configs = {
        "baseline": ClusterConfig(**base),
        "tiered": ClusterConfig(
            **base,
            tiering=TieringConfig(enabled=True, spill_blocks=spill_blocks),
        ),
    }
    out = {
        "oversubscription": oversub,
        "zipf_skew": skew,
        "working_set_blocks": working_set,
        "fast_blocks": fast_blocks,
        "spill_blocks": spill_blocks,
    }
    for name, cfg in configs.items():
        out[name] = _measure(
            cfg, layout, skew, n, n_docs, in_len, out_len, rate
        )
    out["ttft_ratio"] = out["baseline"]["avg_ttft_s"] / max(
        out["tiered"]["avg_ttft_s"], 1e-12
    )
    return out


def tier_chain_cell(
    oversub: float,
    skew: float,
    n: int,
    n_docs: int,
    in_len: int,
    out_len: int = 8,
    rate: float = 8.0,
    n_engines: int = 4,
) -> dict:
    """2-tier vs 3-tier vs destroy-on-evict at the same fast capacity.

    The RDMA-DRAM spill budget is held FIXED (1x fast — far-NUMA memory
    is a constrained resource, it does not scale with demand); the
    3-level chain then hangs a deep SSD-class tier below it (cheap
    capacity).  At >=2x oversubscription the 2-tier chain must
    evict-to-destroy from its bottom while the 3-tier chain demotes the
    cold tail further down and keeps it fetchable at SSD latency — the
    ITME-style hierarchy argument.
    """
    layout = qwen32b_layout()
    working_set = n_docs * (in_len // layout.block_tokens)
    shards = 32
    fast_blocks = _round_shards(int(working_set / oversub), shards)
    spill_blocks = _round_shards(fast_blocks, shards)
    deep_blocks = _round_shards(4 * fast_blocks, shards)
    base = _base_cfg(fast_blocks, shards, n_engines)
    configs = {
        "destroy": ClusterConfig(**base),  # flat: evict == destroy
        "two_tier": ClusterConfig(
            **base,
            tiering=TieringConfig(enabled=True, spill_blocks=spill_blocks),
        ),
        "three_tier": ClusterConfig(
            **base,
            tiering=TieringConfig(
                enabled=True,
                spill_blocks=spill_blocks,
                extra_tiers=((deep_blocks, "ssd"),),
            ),
        ),
    }
    out = {
        "oversubscription": oversub,
        "zipf_skew": skew,
        "working_set_blocks": working_set,
        "fast_blocks": fast_blocks,
        "spill_blocks": spill_blocks,
        "deep_blocks": deep_blocks,
    }
    for name, cfg in configs.items():
        out[name] = _measure(
            cfg, layout, skew, n, n_docs, in_len, out_len, rate
        )
    out["ttft_ratio_3t"] = out["destroy"]["avg_ttft_s"] / max(
        out["three_tier"]["avg_ttft_s"], 1e-12
    )
    out["ttft_ratio_2t"] = out["destroy"]["avg_ttft_s"] / max(
        out["two_tier"]["avg_ttft_s"], 1e-12
    )
    return out


# ---------------------------------------------------------------------------
def zero_cost_check() -> dict:
    """tiering=off must reproduce the PR-1 exp05-small stats bit-exactly."""
    layout = qwen32b_layout()
    cfg = ClusterConfig(
        n_engines=4,
        transfer_mode="beluga",
        pool_blocks=8192,
        hbm_slots_per_engine=1024,
        tiering=TieringConfig(enabled=False),
    )
    s1, s2, _ = run_populate_then_hit(cfg, layout, n=64, in_len=2048, out_len=64)
    got = {
        "populate": {k: s1[k] for k in REFERENCE_PR1["populate"]},
        "cache_hit": {k: s2[k] for k in REFERENCE_PR1["cache_hit"]},
    }
    return {
        "identical": got == REFERENCE_PR1,
        "got": got,
        "reference": REFERENCE_PR1,
    }


# ---------------------------------------------------------------------------
def run(fast: bool = False) -> list[tuple]:
    if fast:
        cells = [(2.0, 1.1)]
        chain_cells = [(2.0, 1.1)]
        n, n_docs, in_len = 64, 16, 1024
    else:
        cells = [(1.0, 1.1), (2.0, 0.8), (2.0, 1.1), (4.0, 1.1)]
        chain_cells = [(2.0, 1.1), (4.0, 1.1)]
        n, n_docs, in_len = 96, 24, 2048

    results: dict = {"fast": fast, "cells": [], "tier_chain": []}
    rows = []
    for oversub, skew in cells:
        cell = sweep_cell(oversub, skew, n=n, n_docs=n_docs, in_len=in_len)
        results["cells"].append(cell)
        t = cell["tiered"]["stats"]
        rows.append(
            (
                f"exp13.tiering.os{oversub:g}.zipf{skew:g}",
                f"{cell['tiered']['avg_ttft_s'] * 1e6:.0f}",
                f"ttft_flat={cell['baseline']['avg_ttft_s'] * 1e3:.0f}ms;"
                f"ttft_tiered={cell['tiered']['avg_ttft_s'] * 1e3:.0f}ms;"
                f"ratio={cell['ttft_ratio']:.2f}x;"
                f"demotions={t.get('demotions', 0)};"
                f"promotions={t.get('promotions', 0)};"
                f"spill_hits={t.get('spill_hit_blocks', 0)}",
            )
        )
    for oversub, skew in chain_cells:
        cell = tier_chain_cell(
            oversub, skew, n=n, n_docs=n_docs, in_len=in_len
        )
        results["tier_chain"].append(cell)
        t3 = cell["three_tier"]["stats"]
        rows.append(
            (
                f"exp13.tier_chain.os{oversub:g}.zipf{skew:g}",
                f"{cell['three_tier']['avg_ttft_s'] * 1e6:.0f}",
                f"ttft_destroy={cell['destroy']['avg_ttft_s'] * 1e3:.0f}ms;"
                f"ttft_2t={cell['two_tier']['avg_ttft_s'] * 1e3:.0f}ms;"
                f"ttft_3t={cell['three_tier']['avg_ttft_s'] * 1e3:.0f}ms;"
                f"ratio_3t={cell['ttft_ratio_3t']:.2f}x;"
                f"tier_writes={t3.get('tier_writes')};"
                f"spill_evictions={t3.get('spill_evictions', 0)}",
            )
        )

    zc = zero_cost_check()
    results["zero_cost"] = zc
    rows.append(
        ("exp13.zero_cost_when_disabled", "0", f"identical={zc['identical']}")
    )

    out_path = OUT_PATH_FAST if fast else OUT_PATH
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI-sized inputs")
    args = ap.parse_args()
    emit(run(fast=args.fast))
    print(f"# wrote {OUT_PATH_FAST if args.fast else OUT_PATH}")

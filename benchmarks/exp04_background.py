"""Exp #4 (Fig. 8): 64 B op latency under background bandwidth pressure.

Server B streams 0..15 GB/s at one memory device while server A issues 64 B
ops at the same device: median stays flat, p99 rises with same-direction
pressure (the paper's bidirectional-capability observation).
"""

import numpy as np

from repro.core.fabric import DEFAULT, DeviceQueues


def run() -> list[tuple]:
    rows = []
    size = 64
    for bg_gbps in (0, 5, 10, 15):
        q = DeviceQueues(n_devices=1, dev_bw=DEFAULT.cxl_dev_bw)
        # background: chunks arriving to sustain bg_gbps
        chunk = 256 * 1024
        horizon = 0.01
        t, lat = 0.0, []
        bg_interval = chunk / (bg_gbps * 2**30) if bg_gbps else None
        bg_t = 0.0
        rng = np.random.default_rng(1)
        for i in range(2000):
            now = i * horizon / 2000
            if bg_interval:
                while bg_t <= now:
                    q.submit(bg_t, 0, chunk, interleave=False)
                    bg_t += bg_interval
            base = DEFAULT.cxl_64b_latency
            done = q.submit(now, 0, size, interleave=False)
            lat.append((done - now) + base)
        lat_us = np.array(lat) * 1e6
        rows.append(
            (f"exp04.bg_{bg_gbps}GBps", f"{np.median(lat_us):.3f}",
             f"p99={np.percentile(lat_us, 99):.3f}us")
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

"""Exp #10 (Table 6): sparse KVCache reads (top-k token selection).

(a) Sparsity analysis: run the REAL reduced model, take attention-score
    top-k tokens per (layer, head) (H2O-style), measure contiguity of the
    selection (paper: >74% non-contiguous for Qwen-32B).
(b) Latency of loading KV for 16 sparse tokens: Beluga single fused kernel
    vs RDMA's per-piece requests (paper: 95.9% reduction, 211us vs 5260us).
"""

import numpy as np

from benchmarks.common import emit
from repro.configs.registry import get_config
from repro.core.pool import BelugaPool, PoolLayout
from repro.core.transfer import TransferEngine


def _contiguity_from_real_model(seq: int = 256, top: int = 32) -> float:
    """Top-k attention-score token selection on a real reduced model."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import RuntimeConfig
    from repro.configs.registry import reduced_config
    from repro.models import Model
    from repro.models import attention as attn_lib
    from repro.models.layers import norm_apply

    cfg = reduced_config("qwen3-32b")
    m = Model(cfg, RuntimeConfig(remat="none", attn_chunk_q=64, attn_chunk_kv=64))
    params = m.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (1, seq), 0, cfg.vocab_size)
    x, positions = m.embed(params, {"tokens": tokens})
    # layer-0 attention scores of the last query against all keys
    pp = jax.tree.map(lambda a: a[0], params["stack"]["pos_0"])
    h = norm_apply(pp["ln1"], x, cfg)
    q, k, v = attn_lib.qkv_proj(pp["attn"], h, cfg, positions, None)
    k = attn_lib._repeat_kv(k, q.shape[2] // k.shape[2])  # GQA broadcast
    scores = jnp.einsum("bshd,bthd->bhst", q[:, -1:], k)  # (1, h, 1, seq)
    sel = jax.lax.top_k(scores[0, :, 0, :], top)[1]  # (heads, top)
    sel = np.asarray(jnp.sort(sel, axis=-1))
    noncontig = 0
    total = 0
    for row in sel:
        diffs = np.diff(row)
        noncontig += int((diffs != 1).sum())
        total += len(diffs)
    return noncontig / max(total, 1)


def run() -> list[tuple]:
    rows = []
    frac = _contiguity_from_real_model()
    rows.append(
        ("exp10.noncontiguous_fraction", f"{100*frac:.1f}",
         "paper: >74% of top-256 selections non-contiguous (Qwen-32B)")
    )
    for arch, paper_rdma, paper_cxl in [
        ("llama3.1-8b", 2670, 97),
        ("qwen3-32b", 5260, 211),
    ]:
        layout = PoolLayout.for_model(get_config(arch))
        res = {}
        for mode in ("beluga", "rdma"):
            pool = BelugaPool(layout, n_blocks=16, n_shards=8, backing="meta")
            eng = TransferEngine(pool, mode=mode)
            res[mode] = eng.sparse_read_latency(16, contiguous_frac=1 - frac) * 1e6
        cut = 1 - res["beluga"] / res["rdma"]
        rows.append(
            (f"exp10.sparse16.{arch}", f"{res['beluga']:.0f}",
             f"rdma={res['rdma']:.0f}us;cut={100*cut:.1f}% "
             f"(paper: cxl={paper_cxl}us rdma={paper_rdma}us, -95.9%)")
        )
    # real sparse gather kernel: one launch for all pieces
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    kv = jnp.arange(64 * 2 * 32, dtype=jnp.float32).reshape(64, 2, 32)
    ids = jnp.asarray([3, 9, 11, 40, 41, 63], jnp.int32)
    out = ops.sparse_kv_gather(kv, ids, mode="pallas")
    ok = bool(jnp.array_equal(out, ref.sparse_kv_gather_ref(kv, ids)))
    rows.append(("exp10.kernel_allclose", "1", f"ok={ok}"))
    return rows


if __name__ == "__main__":
    emit(run())

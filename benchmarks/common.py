"""Shared helpers for the benchmark harness (one module per paper exp)."""

from __future__ import annotations

import random

import numpy as np

from repro.core.pool import PoolLayout
from repro.serving.request import Request


def emit(rows: list[tuple]) -> None:
    """CSV rows: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


def qwen32b_layout(block_tokens: int = 16) -> PoolLayout:
    from repro.configs.registry import get_config

    return PoolLayout.for_model(get_config("qwen3-32b"), block_tokens)


def lveval_requests(
    n: int,
    in_len: int = 15000,
    out_len: int = 64,
    prefix_frac: float = 0.3,
    rate: float | None = None,
    tag: str = "r",
    arrival0: float = 0.0,
    seed: int = 1,
) -> list[Request]:
    """LV-Eval-like workload: long contexts, ~prefix_frac shared prefix.

    Token streams are drawn with vectorized numpy generators (the seed's
    per-token ``random.randrange`` loop dominated benchmark wall-clock);
    the workload STRUCTURE — shared base prefix, per-request deterministic
    suffix (same seed => same tokens across populate/hit phases), arrival
    process — is unchanged, which is all the prefix-cache statistics see.
    """
    cut = int(in_len * prefix_frac)
    base = np.random.default_rng(seed).integers(0, 1000, size=in_len).tolist()
    reqs, t = [], arrival0
    arr_rng = random.Random(seed + 7)
    for i in range(n):
        suffix = (
            np.random.default_rng(1000 + i)
            .integers(0, 1000, size=in_len - cut)
            .tolist()
        )
        reqs.append(
            Request(
                req_id=f"{tag}{i}", tokens=base[:cut] + suffix,
                n_output=out_len, arrival=t,
            )
        )
        if rate:
            t += arr_rng.expovariate(rate)
    return reqs


def run_populate_then_hit(cluster_cfg, layout, n=256, in_len=15000, out_len=64):
    """Two-phase LV-Eval protocol from Exp #5; returns (populate, hit) stats."""
    from repro.serving.request import summarize
    from repro.serving.scheduler import Cluster

    c = Cluster(cluster_cfg, layout)
    for r in lveval_requests(n, in_len, out_len):
        c.dispatch(r)
    s1 = c.run()
    t0 = max(e.clock for e in c.engines)
    for r in lveval_requests(n, in_len, out_len, tag="h", arrival0=t0):
        c.dispatch(r)
    c.run()
    hits = [r for r in c.requests if r.req_id.startswith("h")]
    s2 = summarize(hits, max(x.t_done for x in hits) - t0)
    return s1, s2, c

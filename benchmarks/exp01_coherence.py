"""Exp #1 (Table 4): latency of software cache-coherence methods, 16 KB ops.

Reproduces the paper's coherence-method matrix from the fabric model and
checks the paper's own ordering conclusions (O1-O3): ntstore best for CPU
writes, CLFLUSH-before-read the only viable CPU load, UC memory fine for
DSA, DDIO-off direct for GPU copies.
"""

from repro.core import fabric


def run() -> list[tuple]:
    KB16 = 16 * 1024
    rows = []
    paper = {  # Table 4, microseconds
        "write_store_uc": 281.56, "write_store_clflush": 8.50,
        "write_ntstore": 2.41, "write_dsa_uc": 1.69,
        "read_load_uc": 166.49, "read_load_clflush": 5.98, "read_dsa_uc": 2.12,
        "write_gpu_ddio_off": 9.14, "read_gpu_uc": 10.55,
    }
    ours = {
        "write_store_uc": fabric.cpu_write_latency(KB16, "uncacheable") * 1e6,
        "write_store_clflush": fabric.cpu_write_latency(KB16, "clflush") * 1e6,
        "write_ntstore": fabric.cpu_write_latency(KB16, "ntstore") * 1e6,
        "write_dsa_uc": fabric.cpu_write_latency(KB16, "dsa") * 1e6,
        "read_load_uc": fabric.cpu_read_latency(KB16, "uncacheable") * 1e6,
        "read_load_clflush": fabric.cpu_read_latency(KB16, "clflush") * 1e6,
        "read_dsa_uc": fabric.cpu_read_latency(KB16, "dsa") * 1e6,
        "write_gpu_ddio_off": fabric.gpu_transfer_latency(
            KB16, 1, "fused_kernel", "write") * 1e6,
        "read_gpu_uc": fabric.gpu_transfer_latency(KB16, 1, "fused_kernel") * 1e6,
    }
    for k, v in ours.items():
        rows.append((f"exp01.{k}", f"{v:.2f}", f"paper={paper[k]}us"))
    # the guideline ordering must hold (O1-O3)
    ok = (
        ours["write_ntstore"] < ours["write_store_clflush"] < ours["write_store_uc"]
        and ours["read_load_clflush"] < ours["read_load_uc"]
        and ours["write_dsa_uc"] < ours["write_store_clflush"]
    )
    rows.append(("exp01.guideline_ordering_holds", "0", f"ok={ok}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())

"""Exp #6 (Fig. 11): TTFT/TPOT sensitivity to request arrival rates.

Open-loop Poisson arrivals on a pre-populated pool (all requests hit);
sweeps 0.3..9.0 QPS offered load, Beluga vs MoonCake-RDMA.
"""

from benchmarks.common import emit, lveval_requests, qwen32b_layout
from repro.serving.request import summarize
from repro.serving.scheduler import Cluster, ClusterConfig


def run() -> list[tuple]:
    layout = qwen32b_layout()
    rows = []
    for mode, sbt in [("rdma", 256), ("beluga", 0)]:
        for rate in (0.3, 1.0, 3.0, 6.0, 9.0):
            cfg = ClusterConfig(
                n_engines=16, transfer_mode=mode, pool_blocks=262144,
                super_block_tokens=sbt,
            )
            c = Cluster(cfg, layout)
            # phase 1: populate (warm pool) with the same prompt set
            for r in lveval_requests(96, 15000, 16):
                c.dispatch(r)
            c.run()
            t0 = max(e.clock for e in c.engines)
            # phase 2: open-loop arrivals, all cache hits
            reqs = lveval_requests(96, 15000, 64, rate=rate, tag="h", arrival0=t0)
            for r in reqs:
                c.dispatch(r)
            c.run()
            hits = [r for r in c.requests if r.req_id.startswith("h")]
            s = summarize(hits, max(x.t_done for x in hits) - t0)
            rows.append(
                (f"exp06.{mode}.rate_{rate}", f"{s['avg_ttft_s']*1e6:.0f}",
                 f"ttft={s['avg_ttft_s']:.2f}s;tpot={s['avg_tpot_s']:.3f}s;"
                 f"qps={s['qps']:.2f}")
            )
    return rows


if __name__ == "__main__":
    emit(run())

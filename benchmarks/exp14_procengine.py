"""Exp #14: zero-copy cross-process data plane (engine worker processes).

Two questions, one harness:

  1. PARITY — does moving an engine into its own OS process change ANY
     statistic?  It must not: the worker runs the identical serving
     stack against the identical (now shared) payload bytes, with the
     allocator and metadata planes behind rings either way.  The full
     ``Cluster.run`` stats dict (summaries + index counters + pool
     occupancy) is compared for strict equality across
       private/in-process  vs  shared/in-process  vs  shared/1-worker.

  2. SCALING — N workers scatter/gather against ONE shared segment with
     zero copies through the parent: wall-clock for the same workload at
     N in {1, 2, 4} plus per-engine transfer throughput
     (bytes moved by that worker / wall).  Virtual-time stats stay
     load-invariant; wall numbers are the real-parallelism signal.

CAVEAT (recorded in the artifact as ``host_cores``): on a 2-core CI host
the N=2/N=4 wall-clock understates scaling — 1 core runs the parent +
allocator + metadata services, leaving ~1 for N workers.  Per-engine
throughput at fixed N and the parity bit are the stable signals there.

A third section (``chaos``) drives the FULLY supervised deployment
(``selfheal=True`` + engine worker processes): SIGKILL one worker
between phases — the worker supervisor reconciles its pool leases,
respawns it on a fresh command ring and replays the un-acked submits —
then rolling-restarts the allocator ring under the surviving workers
(command-plane ADOPT cutover).  Reports steady/outage/post throughput
and the kill→respawned recovery time; CI gates on ``restarts == 1``
and bounded ``recovery_s`` from the artifact.

Writes ``BENCH_procengine.json`` (``BENCH_procengine.fast.json`` with
--fast / --smoke).
"""

from __future__ import annotations

import json
import os
import time

from benchmarks.common import lveval_requests
from repro.core.pool import PoolLayout
from repro.serving.scheduler import Cluster, ClusterConfig

OUT_PATH = "BENCH_procengine.json"
OUT_PATH_FAST = "BENCH_procengine.fast.json"

_LAYOUT = PoolLayout(
    block_tokens=16, n_layers_kv=4, n_kv_heads=4, head_dim=32, dtype_bytes=2
)


def _workload(fast: bool):
    if fast:
        return lveval_requests(48, in_len=1024, out_len=16, rate=40.0)
    return lveval_requests(160, in_len=4096, out_len=32, rate=40.0)


def _cfg(fast: bool, n_engines: int, **kw) -> ClusterConfig:
    return ClusterConfig(
        n_engines=n_engines,
        policy="round_robin",
        pool_blocks=1024 if fast else 4096,
        pool_shards=4,
        hbm_slots_per_engine=128 if fast else 512,
        block_tokens=16,
        index_rpc=True,
        index_transport="process",
        index_shards=2,
        **kw,
    )


def _run_once(fast: bool, n_engines: int, **kw) -> tuple[dict, float, list]:
    """One cluster lifecycle over the standard workload; returns
    (run stats, wall seconds, per-worker stats dicts)."""
    cfg = _cfg(fast, n_engines, **kw)
    with Cluster(cfg, _LAYOUT, backing="numpy") as c:
        for r in _workload(fast):
            c.dispatch(r)
        t0 = time.perf_counter()
        stats = c.run()
        wall = time.perf_counter() - t0
        worker_stats = [w.stats_dict() for w in c.workers]
    return stats, wall, worker_stats


def chaos_sweep(fast: bool, n_workers: int = 2) -> dict:
    """Worker-kill + allocator-restart drill against the supervised
    data plane; returns the ``chaos`` artifact cell."""
    from repro.distributed.fault_tolerance import (
        FaultEvent,
        FaultInjector,
        FaultPlan,
    )

    cfg = _cfg(
        fast,
        n_workers,
        data_plane="shared",
        engine_processes=n_workers,
        selfheal=True,
        supervisor_probe_interval=0.01,
    )
    work = _workload(fast)
    third = max(1, len(work) // 3)
    out: dict = {"n_workers": n_workers}
    with Cluster(cfg, _LAYOUT, backing="numpy") as c:
        inj = FaultInjector(
            FaultPlan([
                FaultEvent(t=1.0, kind="kill_worker", shard=0),
                FaultEvent(t=2.0, kind="kill_allocator"),
            ]),
            supervisors=(),
            worker_supervisors=c.workers,
            allocator=c.restart_allocator,
        ).start()

        # steady: no faults yet
        for r in work[:third]:
            c.dispatch(r)
        t0 = time.perf_counter()
        c.run()
        out["steady_qps_wall"] = third / max(time.perf_counter() - t0, 1e-9)

        # outage: SIGKILL worker 0, then keep dispatching — the first
        # submit routed to the dead worker drives the supervisor's heal
        # path (detect -> reconcile leases -> respawn -> replay)
        t_kill = time.perf_counter()
        inj.advance(now=1.0)
        recovery_s = None
        for r in work[third:2 * third]:
            c.dispatch(r)
            if recovery_s is None and c.workers[0].restarts >= 1:
                recovery_s = time.perf_counter() - t_kill
        c.run()
        if recovery_s is None and c.workers[0].restarts >= 1:
            # round-robin skipped worker 0 during dispatch; the run()
            # collect path healed it instead
            recovery_s = time.perf_counter() - t_kill
        out["outage_qps_wall"] = third / max(
            time.perf_counter() - t_kill, 1e-9
        )
        out["recovery_s"] = recovery_s

        # post: allocator rolling restart (ADOPT cutover), then the
        # final phase must run at full speed on the new ring generation
        inj.advance(now=2.0)
        for r in work[2 * third:]:
            c.dispatch(r)
        t2 = time.perf_counter()
        stats = c.run()
        out["post_qps_wall"] = (len(work) - 2 * third) / max(
            time.perf_counter() - t2, 1e-9
        )

        out["restarts"] = stats["selfheal"]["worker_restarts"]
        out["allocator_restarts"] = stats["selfheal"]["allocator_restarts"]
        out["leases_released"] = stats["selfheal"]["leases_released"]
        out["rpc_retries"] = stats["selfheal"]["rpc_retries"]
        out["n_done"] = stats["n_done"]
        out["pool_free"] = stats["pool_free"]
    return out


def run(fast: bool = False) -> list[tuple]:
    rows: list[tuple] = []
    results: dict = {"host_cores": os.cpu_count()}

    # -- 1. parity: the process boundary must be statistically invisible
    ref, _, _ = _run_once(fast, 1, data_plane="private")
    shared_inproc, _, _ = _run_once(fast, 1, data_plane="shared")
    worker1, wall1, wstats1 = _run_once(
        fast, 1, data_plane="shared", engine_processes=1
    )
    bit_identical = ref == shared_inproc == worker1
    results["parity"] = {
        "bit_identical": bit_identical,
        "n_done": ref["n_done"],
        "avg_ttft_s": ref["avg_ttft_s"],
        "hit_tokens": ref["hit_tokens"],
        "pool_free": ref["pool_free"],
    }
    if not bit_identical:
        results["parity"]["private"] = _jsonable(ref)
        results["parity"]["shared_inproc"] = _jsonable(shared_inproc)
        results["parity"]["worker1"] = _jsonable(worker1)
    rows.append((
        "procengine.parity", 0.0,
        f"bit_identical={bit_identical};n_done={ref['n_done']}",
    ))

    # -- 2. scaling: N workers against one shared segment
    results["sweep"] = []
    for n in (1, 2, 4):
        if n == 1:
            stats, wall, wstats = worker1, wall1, wstats1
        else:
            stats, wall, wstats = _run_once(
                fast, n, data_plane="shared", engine_processes=n
            )
        moved = [
            ws["transfer"]["bytes_written"] + ws["transfer"]["bytes_read"]
            for ws in wstats
        ]
        per_engine_mb_s = (sum(moved) / max(1, len(moved))) / max(
            wall, 1e-9
        ) / 1e6
        cell = {
            "n_workers": n,
            "wall_s": wall,
            "qps_wall": stats["n_done"] / max(wall, 1e-9),
            "per_engine_mb_s": per_engine_mb_s,
            "bytes_moved_total": sum(moved),
            "n_done": stats["n_done"],
            "hit_tokens": stats["hit_tokens"],
        }
        results["sweep"].append(cell)
        rows.append((
            f"procengine.N{n}", wall * 1e6 / max(1, stats["n_done"]),
            f"wall_s={wall:.3f};per_engine_mb_s={per_engine_mb_s:.1f};"
            f"qps_wall={cell['qps_wall']:.1f}",
        ))

    # -- 3. chaos: kill a worker + restart the allocator under load
    ch = chaos_sweep(fast)
    results["chaos"] = ch
    rec = ch["recovery_s"]
    rows.append((
        "procengine.chaos",
        (rec or 0.0) * 1e6,
        f"restarts={ch['restarts']};"
        f"recovery_s={'none' if rec is None else f'{rec:.3f}'};"
        f"alloc_restarts={ch['allocator_restarts']};"
        f"post_qps_wall={ch['post_qps_wall']:.1f}",
    ))

    results["note"] = (
        "wall-clock on a <=2-core host understates >=2-worker scaling "
        "(parent + allocator + metadata services share the cores); "
        "virtual-time stats are load-invariant"
    )
    with open(OUT_PATH_FAST if fast else OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
    if not bit_identical:
        raise AssertionError(
            "engine-worker parity broke: shared/worker stats diverged "
            "from the private in-process reference (see artifact)"
        )
    return rows


def _jsonable(d: dict) -> dict:
    return json.loads(json.dumps(d, default=str))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    from benchmarks.common import emit

    emit(run(fast=args.fast))

"""Exp #2 (Fig. 5): latency vs I/O size for every CPU/GPU x pool path.

Checks the paper's two crossovers: CPU load/store beats DSA below ~4 KB
(O4), and the custom fused kernel beats per-fragment cudaMemcpy for small
transfers on UC memory (O6, <24 KB pathology).
"""

from repro.core import fabric


SIZES = [256, 1024, 4096, 16384, 65536, 262144, 1048576]


def run() -> list[tuple]:
    rows = []
    cross_cpu = None
    for s in SIZES:
        cpu_direct = fabric.cpu_write_latency(s, "ntstore") * 1e6
        cpu_dsa = fabric.cpu_write_latency(s, "dsa") * 1e6
        gpu_fused = fabric.gpu_transfer_latency(s, 1, "fused_kernel") * 1e6
        gpu_memcpy = fabric.gpu_transfer_latency(s, 1, "cudamemcpy") * 1e6
        rdma = fabric.rdma_transfer_latency(s, 1) * 1e6
        dram = fabric.local_dram_latency(s) * 1e6
        rows.append(
            (f"exp02.write_{s}B", f"{cpu_direct:.2f}",
             f"dsa={cpu_dsa:.2f};gpu_fused={gpu_fused:.2f};"
             f"gpu_memcpy={gpu_memcpy:.2f};rdma={rdma:.2f};dram={dram:.2f}")
        )
        if cross_cpu is None and cpu_dsa < cpu_direct:
            cross_cpu = s
    rows.append(
        ("exp02.dsa_crossover_bytes", str(cross_cpu),
         "paper: DSA wins above ~4-16KB (O4)")
    )
    small = fabric.gpu_transfer_latency(16384, 1, "cudamemcpy", "read") * 1e6
    fused = fabric.gpu_transfer_latency(16384, 1, "fused_kernel", "read") * 1e6
    rows.append(
        ("exp02.gpu_16k_uc_memcpy_vs_fused", f"{small:.1f}",
         f"fused={fused:.1f}us; paper: memcpy ~1230us <24KB on UC (O6)")
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
